//! Stage 4 — **residency**: which prepared matrices stay resident, sized
//! by **bytes** rather than entry count, shared read-only across workers,
//! and rebuilt at a smaller shard count when serving metrics show skew.
//!
//! Serpens (arXiv 2111.12555) makes the case at the memory level: residency
//! placement, not compute, decides throughput. This stage owns that
//! decision for the serving pipeline:
//!
//! * **Shared handles, lock-free execution** — a prepared handle is built
//!   once (through the backend's `prepare_send`) and shared by every
//!   worker as a plain `Arc<dyn PreparedSpmm + Send + Sync>`, instead of
//!   one duplicate residency per worker. Because `execute` takes `&self`
//!   (per-call scratch comes from the handle's internal pool), W workers
//!   hammering one hot matrix run W executions *concurrently* — no
//!   per-matrix mutex, no serialization. The only locks left in this
//!   stage guard the cache map itself and the engines' tiny scratch-pool
//!   checkouts. Backends whose handles cannot cross threads (the real
//!   PJRT engine) fall back to per-worker thread-local caches
//!   ([`Resolution::ThreadLocal`]).
//! * **Byte-sized eviction** — the cache budget is
//!   [`ResidencyPolicy::max_resident_bytes`] of actual
//!   [`crate::backend::PrepareCost::resident_bytes`], not a fixed entry
//!   count: eight tiny matrices no longer evict each other while one huge
//!   matrix slips under an entry-based limit.
//! * **Re-shard-on-skew** — every sharded execution feeds its nnz
//!   imbalance into a rolling window; when the window's mean exceeds
//!   [`ReshardPolicy::imbalance_threshold`], the resident handle is
//!   dropped and re-prepared at half its shard count — exactly the
//!   prepare/execute contract's rebuild path, invisible to callers. The
//!   rebuilt spec passes through [`crate::backend::apply_thread_budget`]
//!   again ([`reshard_spec`]) so the new workers × shards × engine-threads
//!   product cannot oversubscribe the machine.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Recorder;
use crate::backend::{self, BackendError, PreparedSpmm, SpmmBackend};
use crate::sched::ScheduledMatrix;
use crate::shard::ShardRunStats;
use crate::telemetry::trace::{SpanRecord, TelemetrySink};

/// Depth of the per-worker *fallback* cache used for backends whose
/// handles cannot cross threads (`prepare_send` refused, e.g. the real
/// PJRT engine). The shared cache is byte-sized instead
/// ([`ResidencyPolicy::max_resident_bytes`]).
pub const PREPARED_CACHE_ENTRIES: usize = 8;

/// Prepared-handle cache policy: sized by resident bytes, not entries.
#[derive(Clone, Copy, Debug)]
pub struct ResidencyPolicy {
    /// Total bytes of prepared-handle state kept resident across all
    /// cached matrices. Least-recently-used residencies are dropped first;
    /// the most recently used handle always stays, even when it alone
    /// exceeds the budget (the server must be able to serve).
    pub max_resident_bytes: u64,
    /// High-water timeout for pooled scratch: slot sets idle longer than
    /// this are dropped by [`ResidencyManager::trim_scratch`] (through
    /// [`crate::backend::PreparedSpmm::trim_resident`]), so a concurrency
    /// burst's scratch surplus is reclaimed instead of held forever.
    /// `None` (the default) disables trimming.
    pub scratch_idle: Option<Duration>,
}

impl Default for ResidencyPolicy {
    fn default() -> Self {
        ResidencyPolicy { max_resident_bytes: 512 * 1024 * 1024, scratch_idle: None }
    }
}

/// Re-shard-on-skew policy.
#[derive(Clone, Copy, Debug)]
pub struct ReshardPolicy {
    /// Rolling mean shard-nnz imbalance (max/mean, 1.0 = balanced) above
    /// which the resident sharded handle is rebuilt at half its shard
    /// count. `f64::INFINITY` (the default) disables resharding.
    pub imbalance_threshold: f64,
    /// Sharded executions accumulated per evaluation window; the trigger
    /// is checked (and the window reset) every `window` executions.
    pub window: usize,
}

impl Default for ReshardPolicy {
    fn default() -> Self {
        ReshardPolicy { imbalance_threshold: f64::INFINITY, window: 16 }
    }
}

/// What a rebuild needs that the budgeted factory spec no longer carries:
/// the inner engine spec exactly as the operator gave it (un-budgeted) and
/// the per-worker core budget computed at server startup.
#[derive(Clone, Debug)]
pub struct ReshardContext {
    /// Raw inner spec of the `sharded:<S>:<inner>` composite, before
    /// thread budgeting.
    pub inner_spec: String,
    /// Core budget per server worker.
    pub budget: usize,
}

/// Compose the registry spec for a rebuilt sharded handle: `new_s` shards
/// over the raw inner spec, re-budgeted for `budget` cores. Rebuilding
/// from the *budgeted* spec instead would freeze the inner thread count at
/// the old S's share — after S halves, half the cores would sit idle (or,
/// resharding upward, be oversubscribed).
pub fn reshard_spec(inner_spec: &str, new_s: usize, budget: usize) -> String {
    backend::apply_thread_budget(&format!("sharded:{new_s}:{inner_spec}"), budget)
}

/// A prepared handle shared across workers. Execution goes straight
/// through `&self` — workers clone the `Arc` and execute concurrently, so
/// W workers hammering a *single* hot matrix get W simultaneous
/// executions (each on its own pooled scratch) instead of serializing on
/// a per-matrix mutex. One residency, full concurrency: the memory win no
/// longer costs the single-hot-matrix workload anything.
pub type SharedHandle = Arc<dyn PreparedSpmm + Send + Sync>;

/// Outcome of a residency lookup.
pub enum Resolution {
    /// The matrix is resident (or just became resident) in the shared
    /// cache; execute through this handle.
    Shared(SharedHandle),
    /// This backend's handles cannot cross threads; the worker must
    /// prepare thread-locally and keep its own fallback cache.
    ThreadLocal,
}

struct Entry {
    id: u64,
    image: Arc<ScheduledMatrix>,
    handle: SharedHandle,
    bytes: u64,
    /// Current shard count of a composite handle (`None` = single-unit).
    shards: Option<usize>,
    /// Sharded executions since the last rebuild or window reset.
    execs: usize,
    /// Sum of per-execution nnz imbalance over `execs`.
    imbalance_sum: f64,
    /// A skew rebuild is in flight (built outside the lock; the old
    /// handle keeps serving until the swap).
    rebuilding: bool,
}

struct State {
    /// MRU-first.
    entries: Vec<Entry>,
    total_bytes: u64,
    /// Image ids with a prepare in flight (built outside the lock so
    /// other matrices resolve without stalling; same-id resolvers wait on
    /// the condvar instead of preparing twice).
    preparing: Vec<u64>,
    /// Image ids whose backend refused `prepare_send` once: resolved
    /// straight to [`Resolution::ThreadLocal`] from then on, so the
    /// steady-state hot path of thread-local backends (the real PJRT
    /// engine) never re-runs the miss protocol.
    thread_local: Vec<u64>,
    /// When the last scratch trim sweep ran (rate limit).
    last_trim: Instant,
}

/// Drop LRU residencies until the pool fits the byte budget. The MRU
/// entry always stays, even oversized (the server must be able to serve),
/// and entries with a rebuild in flight are passed over — evicting one
/// would throw away the re-prepare its rebuild is paying for; the budget
/// is re-enforced when that rebuild lands.
fn evict_to_budget(policy: &ResidencyPolicy, st: &mut State, recorder: &Mutex<Recorder>) {
    while st.total_bytes > policy.max_resident_bytes && st.entries.len() > 1 {
        let Some(victim_idx) = st.entries.iter().rposition(|e| !e.rebuilding) else {
            break;
        };
        if victim_idx == 0 {
            break;
        }
        let victim = st.entries.remove(victim_idx);
        st.total_bytes -= victim.bytes;
        recorder.lock().unwrap().record_evict();
    }
}

/// The residency manager: one per server, shared by all workers.
pub struct ResidencyManager {
    policy: ResidencyPolicy,
    reshard: ReshardPolicy,
    ctx: Option<ReshardContext>,
    state: Mutex<State>,
    /// Signaled when an in-flight prepare (see `State::preparing`) ends.
    prepare_done: Condvar,
    /// Telemetry sink: cache misses emit a `backend.prepare` span covering
    /// the unlocked build (`None` disables emission).
    sink: Option<Arc<dyn TelemetrySink>>,
}

impl ResidencyManager {
    /// Build a manager. `ctx` enables re-shard-on-skew and is only
    /// available when the server was started from a registry spec (a
    /// closure factory has no spec to rebuild from). `sink` receives a
    /// `backend.prepare` span per actual (miss-path) handle build.
    pub fn new(
        policy: ResidencyPolicy,
        reshard: ReshardPolicy,
        ctx: Option<ReshardContext>,
        sink: Option<Arc<dyn TelemetrySink>>,
    ) -> ResidencyManager {
        ResidencyManager {
            policy,
            reshard,
            ctx,
            state: Mutex::new(State {
                entries: Vec::new(),
                total_bytes: 0,
                preparing: Vec::new(),
                thread_local: Vec::new(),
                last_trim: Instant::now(),
            }),
            prepare_done: Condvar::new(),
            sink,
        }
    }

    /// Resolve a registered image to its shared prepared handle: a hit
    /// bubbles the entry to the MRU front; a miss prepares through
    /// `factory` *outside* the state lock (hits and other matrices keep
    /// resolving meanwhile; same-id resolvers wait on a condvar so one
    /// matrix is never prepared twice concurrently) and may evict
    /// least-recently-used residencies to fit the byte budget. Returns
    /// [`Resolution::ThreadLocal`] when the factory refuses
    /// `prepare_send`.
    pub fn resolve(
        &self,
        id: u64,
        image: &Arc<ScheduledMatrix>,
        factory: &dyn SpmmBackend,
        recorder: &Mutex<Recorder>,
    ) -> Resolution {
        self.resolve_traced(id, image, factory, recorder, None)
    }

    /// [`ResidencyManager::resolve`] with telemetry attribution: when the
    /// lookup misses and this call pays the build, a `backend.prepare`
    /// span (child of `trace`'s `(trace_id, parent_span_id)`) covering the
    /// unlocked `prepare_send` is emitted to the configured sink. Hits
    /// emit nothing — that is the point of the amortization.
    pub(crate) fn resolve_traced(
        &self,
        id: u64,
        image: &Arc<ScheduledMatrix>,
        factory: &dyn SpmmBackend,
        recorder: &Mutex<Recorder>,
        trace: Option<(u64, u64)>,
    ) -> Resolution {
        let mut guard = self.state.lock().unwrap();
        loop {
            // Re-checked after every condvar wake: a concurrent resolver
            // may have latched this image as thread-local meanwhile.
            if guard.thread_local.contains(&id) {
                return Resolution::ThreadLocal;
            }
            if let Some(i) = guard.entries.iter().position(|e| e.id == id) {
                if i != 0 {
                    let e = guard.entries.remove(i);
                    guard.entries.insert(0, e);
                }
                recorder.lock().unwrap().record_prepare_hit();
                return Resolution::Shared(Arc::clone(&guard.entries[0].handle));
            }
            if guard.preparing.contains(&id) {
                // Another worker is building this matrix; when it finishes
                // we re-check and hit (or, if it failed, build ourselves).
                guard = self.prepare_done.wait(guard).unwrap();
            } else {
                break;
            }
        }
        guard.preparing.push(id);
        drop(guard);

        // Miss: the build path, run unlocked. Thread-local backends (and
        // genuinely failing prepares) fall back to the worker, which
        // surfaces the engine's own error per request.
        let t_build = Instant::now();
        let prepared = factory.prepare_send(Arc::clone(image));
        if let (Some(sink), Some((trace_id, parent))) = (self.sink.as_ref(), trace) {
            let span = SpanRecord::from_instants(
                trace_id,
                Some(parent),
                "backend.prepare",
                t_build,
                Instant::now(),
            )
            .tag("backend", factory.name().to_string())
            .tag("outcome", if prepared.is_ok() { "built" } else { "refused" });
            sink.emit(span);
        }

        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        st.preparing.retain(|p| *p != id);
        self.prepare_done.notify_all();
        let handle = match prepared {
            Ok(h) => h,
            Err(e) => {
                // Latch only the definitive refusal (`Unavailable` is what
                // both the default "handles are thread-local" refusal and
                // an artifact-less engine return): those never start
                // succeeding, so skip the miss protocol from now on. Other
                // errors may be transient — keep retrying the shared path
                // so the byte-budgeted cache isn't silently disabled. The
                // worker-local fallback re-attempts `prepare` per request
                // either way, so errors still surface there.
                if matches!(e, BackendError::Unavailable(_)) {
                    st.thread_local.push(id);
                }
                return Resolution::ThreadLocal;
            }
        };
        let cost = handle.prepare_cost();
        recorder.lock().unwrap().record_prepare(&cost);
        let shards = handle.resident_shards();
        let shared: SharedHandle = Arc::from(handle);
        st.entries.insert(
            0,
            Entry {
                id,
                image: Arc::clone(image),
                handle: Arc::clone(&shared),
                bytes: cost.resident_bytes,
                shards,
                execs: 0,
                imbalance_sum: 0.0,
                rebuilding: false,
            },
        );
        st.total_bytes += cost.resident_bytes;
        // Byte-sized eviction. Workers still executing an evicted handle
        // hold their own Arc clone; the bytes are freed when the last of
        // them finishes.
        evict_to_budget(&self.policy, st, recorder);
        Resolution::Shared(shared)
    }

    /// Feed one sharded execution's stats into the rolling skew window for
    /// `id`. When the window fills and its mean imbalance exceeds the
    /// policy threshold, the resident handle is dropped and re-prepared at
    /// half its shard count (via [`reshard_spec`], so thread budgets are
    /// re-derived for the new S) — callers never notice beyond the one-off
    /// rebuild latency. A failed rebuild keeps the old handle serving.
    pub fn note_shards(&self, id: u64, stats: &ShardRunStats, recorder: &Mutex<Recorder>) {
        let Some(ctx) = &self.ctx else { return };
        if self.reshard.imbalance_threshold.is_infinite() {
            return;
        }
        // Phase 1 — under the lock: accumulate the window and decide.
        let (image, s, new_s) = {
            let mut guard = self.state.lock().unwrap();
            let Some(e) = guard.entries.iter_mut().find(|e| e.id == id) else { return };
            let Some(s) = e.shards else { return };
            // Stats from a handle retired by an earlier rebuild (stale S),
            // or arriving while a rebuild is in flight, must not poison
            // the new pool's window and double-trigger.
            if e.rebuilding || stats.shards != s {
                return;
            }
            e.execs += 1;
            e.imbalance_sum += stats.imbalance;
            if e.execs < self.reshard.window.max(1) {
                return;
            }
            let mean = e.imbalance_sum / e.execs as f64;
            e.execs = 0;
            e.imbalance_sum = 0.0;
            if mean <= self.reshard.imbalance_threshold || s <= 1 {
                return;
            }
            e.rebuilding = true;
            (Arc::clone(&e.image), s, (s / 2).max(1))
        };
        // Phase 2 — unlocked: build the new pool while the old one keeps
        // serving (hits and other matrices never stall behind a rebuild).
        let spec = reshard_spec(&ctx.inner_spec, new_s, ctx.budget);
        let rebuilt =
            backend::create(&spec).and_then(|factory| factory.prepare_send(image));
        // Phase 3 — under the lock: swap the handle in (or keep the old
        // one on a failed rebuild) and re-enforce the byte budget.
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        let Some(e) = st.entries.iter_mut().find(|e| e.id == id) else { return };
        e.rebuilding = false;
        let Ok(handle) = rebuilt else { return };
        let cost = handle.prepare_cost();
        recorder.lock().unwrap().record_reshard(s, new_s);
        st.total_bytes = st.total_bytes + cost.resident_bytes - e.bytes;
        e.bytes = cost.resident_bytes;
        e.shards = handle.resident_shards();
        e.execs = 0;
        e.imbalance_sum = 0.0;
        // Replacing the Arc retires the old pool: workers mid-execute on
        // it finish safely on their own clones.
        e.handle = Arc::from(handle);
        evict_to_budget(&self.policy, st, recorder);
    }

    /// Refresh the byte accounting of `id` from a live measurement
    /// ([`crate::backend::PreparedSpmm::resident_bytes_now`]). Scratch
    /// pools grow after prepare — with request width and with peak
    /// concurrency — so the prepare-time estimate undercounts hot handles;
    /// the dispatch stage calls this after each execution so the
    /// byte-budgeted LRU charges handles for what they actually hold, and
    /// the budget is re-enforced when the pool grew.
    pub fn note_bytes(&self, id: u64, bytes: u64, recorder: &Mutex<Recorder>) {
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        let Some(e) = st.entries.iter_mut().find(|e| e.id == id) else { return };
        if e.bytes == bytes {
            return;
        }
        st.total_bytes = st.total_bytes - e.bytes + bytes;
        e.bytes = bytes;
        evict_to_budget(&self.policy, st, recorder);
    }

    /// Trim idle pooled scratch across every resident handle (rate-limited
    /// to once per half [`ResidencyPolicy::scratch_idle`]), refreshing the
    /// byte accounting of handles that shrank. Returns the bytes
    /// reclaimed. The dispatch stage calls this after executions, so a
    /// server that saw a concurrency burst sheds the burst's scratch
    /// surplus once the slots go idle past the high-water timeout — the
    /// reclaim is visible in [`ResidencyManager::resident_bytes`] and the
    /// handles' own `resident_bytes_now`. A no-op when the policy leaves
    /// `scratch_idle` unset.
    pub fn trim_scratch(&self, recorder: &Mutex<Recorder>) -> u64 {
        let Some(max_idle) = self.policy.scratch_idle else { return 0 };
        let handles: Vec<(u64, SharedHandle)> = {
            let mut guard = self.state.lock().unwrap();
            if guard.last_trim.elapsed() < max_idle / 2 {
                return 0;
            }
            guard.last_trim = Instant::now();
            guard.entries.iter().map(|e| (e.id, Arc::clone(&e.handle))).collect()
        };
        // Trim outside the lock: trait objects may take their own pool
        // locks, and resolution must not stall behind the sweep.
        let mut reclaimed = 0u64;
        for (id, handle) in handles {
            let got = handle.trim_resident(max_idle);
            if got > 0 {
                reclaimed += got;
                self.note_bytes(id, handle.resident_bytes_now(), recorder);
            }
        }
        reclaimed
    }

    /// Total bytes currently resident across cached handles.
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().total_bytes
    }

    /// Number of resident matrices.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current shard count of the resident handle for `id` (`None` when
    /// not resident or not a composite handle).
    pub fn resident_shards(&self, id: u64) -> Option<usize> {
        self.state
            .lock()
            .unwrap()
            .entries
            .iter()
            .find(|e| e.id == id)
            .and_then(|e| e.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::sched::preprocess;
    use crate::shard::ShardedBackend;
    use crate::sparse::{gen, rng::Rng};
    use std::time::Duration;

    fn image(seed: u64) -> Arc<ScheduledMatrix> {
        let mut rng = Rng::new(seed);
        let coo = gen::random_uniform(48, 40, 0.2, &mut rng);
        Arc::new(preprocess(&coo, 4, 16, 6))
    }

    fn skewed_image() -> Arc<ScheduledMatrix> {
        let mut rng = Rng::new(77);
        let coo = gen::power_law_rows(96, 64, 1_200, 1.2, &mut rng);
        Arc::new(preprocess(&coo, 4, 16, 6))
    }

    fn stats(shards: usize, imbalance: f64) -> ShardRunStats {
        ShardRunStats {
            shards,
            shard_nnz: vec![1; shards],
            shard_latency: vec![Duration::from_micros(1); shards],
            imbalance,
        }
    }

    #[test]
    fn hit_shares_one_handle_and_records() {
        let mgr = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy::default(),
            None,
            None,
        );
        let recorder = Mutex::new(Recorder::default());
        let be = NativeBackend::new(1);
        let img = image(1);
        let a = mgr.resolve(7, &img, &be, &recorder);
        let b = mgr.resolve(7, &img, &be, &recorder);
        let (Resolution::Shared(a), Resolution::Shared(b)) = (a, b) else {
            panic!("native prepares sendable handles");
        };
        assert!(Arc::ptr_eq(&a, &b), "both workers must share one residency");
        assert_eq!(mgr.len(), 1);
        let s = recorder.lock().unwrap().summary();
        assert_eq!(s.prepares, 1);
        assert_eq!(s.prepare_hits, 1);
    }

    #[test]
    fn eviction_is_by_bytes_not_entries() {
        let recorder = Mutex::new(Recorder::default());
        let be = NativeBackend::new(1);
        // Learn one image's resident footprint, then budget for two.
        let probe = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy::default(),
            None,
            None,
        );
        probe.resolve(0, &image(10), &be, &recorder);
        let one = probe.resident_bytes();
        assert!(one > 0);

        let mgr = ResidencyManager::new(
            ResidencyPolicy { max_resident_bytes: 2 * one + one / 2, ..Default::default() },
            ReshardPolicy::default(),
            None,
            None,
        );
        for (id, seed) in [(1u64, 11u64), (2, 12), (3, 13)] {
            mgr.resolve(id, &image(seed), &be, &recorder);
        }
        // Images are equal-sized, so a 2.5x budget holds two: the LRU
        // (id 1) was evicted.
        assert!(mgr.len() < 3, "byte budget must evict");
        assert!(mgr.resident_bytes() <= 2 * one + one / 2);
        assert!(mgr.resident_shards(1).is_none());
        let s = recorder.lock().unwrap().summary();
        assert!(s.evictions >= 1);
        // An oversized single handle still stays resident.
        let tiny = ResidencyManager::new(
            ResidencyPolicy { max_resident_bytes: 1, ..Default::default() },
            ReshardPolicy::default(),
            None,
            None,
        );
        tiny.resolve(9, &image(14), &be, &recorder);
        assert_eq!(tiny.len(), 1, "the newest handle is never evicted");
    }

    #[test]
    fn refused_prepare_send_falls_back_to_thread_local_and_latches() {
        use crate::backend::{BackendError, Capability, SpmmBackend};
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct LocalOnly(AtomicUsize);
        impl SpmmBackend for LocalOnly {
            fn name(&self) -> &'static str {
                "local-only"
            }
            fn capability(&self) -> Capability {
                Capability {
                    threads: 1,
                    simd_lanes: 1,
                    requires_artifacts: false,
                    deterministic: true,
                }
            }
            fn prepare(
                &self,
                _image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
                Err(BackendError::Unavailable("unit test".into()))
            }
            fn prepare_send(
                &self,
                _image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
                self.0.fetch_add(1, Ordering::Relaxed);
                Err(BackendError::Unavailable("thread-local handles".into()))
            }
        }
        let mgr = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy::default(),
            None,
            None,
        );
        let recorder = Mutex::new(Recorder::default());
        let be = LocalOnly(AtomicUsize::new(0));
        let img = image(20);
        assert!(matches!(
            mgr.resolve(1, &img, &be, &recorder),
            Resolution::ThreadLocal
        ));
        assert!(mgr.is_empty(), "refused handles must not occupy the cache");
        assert_eq!(recorder.lock().unwrap().summary().prepares, 0);
        // The refusal latches: later jobs skip the miss protocol entirely.
        assert!(matches!(
            mgr.resolve(1, &img, &be, &recorder),
            Resolution::ThreadLocal
        ));
        assert_eq!(be.0.load(Ordering::Relaxed), 1, "prepare_send attempted exactly once");
    }

    #[test]
    fn skew_window_triggers_exactly_one_halving() {
        let mgr = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy { imbalance_threshold: 1.5, window: 2 },
            Some(ReshardContext { inner_spec: "functional".into(), budget: 4 }),
            None,
        );
        let recorder = Mutex::new(Recorder::default());
        let be = ShardedBackend::from_spec(4, "functional").unwrap();
        let img = skewed_image();
        mgr.resolve(5, &img, &be, &recorder);
        assert_eq!(mgr.resident_shards(5), Some(4));
        // Window of 2 skewed executions: rebuild at S = 2.
        mgr.note_shards(5, &stats(4, 3.0), &recorder);
        assert_eq!(mgr.resident_shards(5), Some(4), "window not yet full");
        mgr.note_shards(5, &stats(4, 3.0), &recorder);
        assert_eq!(mgr.resident_shards(5), Some(2), "skew must halve the pool");
        let s = recorder.lock().unwrap().summary();
        assert_eq!(s.reshards, 1);
        assert_eq!(s.last_reshard, Some((4, 2)));
        // A balanced window afterwards must not rebuild again.
        mgr.note_shards(5, &stats(2, 1.0), &recorder);
        mgr.note_shards(5, &stats(2, 1.0), &recorder);
        assert_eq!(mgr.resident_shards(5), Some(2));
        assert_eq!(recorder.lock().unwrap().summary().reshards, 1);
    }

    #[test]
    fn stale_stats_from_a_retired_pool_are_ignored() {
        let mgr = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy { imbalance_threshold: 1.5, window: 1 },
            Some(ReshardContext { inner_spec: "functional".into(), budget: 4 }),
            None,
        );
        let recorder = Mutex::new(Recorder::default());
        let be = ShardedBackend::from_spec(8, "functional").unwrap();
        mgr.resolve(6, &skewed_image(), &be, &recorder);
        mgr.note_shards(6, &stats(8, 4.0), &recorder);
        assert_eq!(mgr.resident_shards(6), Some(4), "window of 1: immediate halving");
        // A worker still executing the retired 8-shard pool reports late;
        // its stale stats must not feed the 4-shard window and re-trigger.
        mgr.note_shards(6, &stats(8, 4.0), &recorder);
        mgr.note_shards(6, &stats(8, 4.0), &recorder);
        assert_eq!(mgr.resident_shards(6), Some(4));
        assert_eq!(recorder.lock().unwrap().summary().reshards, 1);
        // Fresh 4-shard stats still drive the window.
        mgr.note_shards(6, &stats(4, 3.0), &recorder);
        assert_eq!(mgr.resident_shards(6), Some(2));
        assert_eq!(recorder.lock().unwrap().summary().reshards, 2);
    }

    #[test]
    fn resharding_disabled_without_context_or_threshold() {
        let recorder = Mutex::new(Recorder::default());
        let be = ShardedBackend::from_spec(4, "functional").unwrap();
        // No context (closure-started server): never reshards.
        let no_ctx = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy { imbalance_threshold: 1.1, window: 1 },
            None,
            None,
        );
        no_ctx.resolve(1, &skewed_image(), &be, &recorder);
        no_ctx.note_shards(1, &stats(4, 9.0), &recorder);
        assert_eq!(no_ctx.resident_shards(1), Some(4));
        // Default (infinite) threshold: never reshards.
        let off = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy::default(),
            Some(ReshardContext { inner_spec: "functional".into(), budget: 4 }),
            None,
        );
        off.resolve(2, &skewed_image(), &be, &recorder);
        for _ in 0..40 {
            off.note_shards(2, &stats(4, 9.0), &recorder);
        }
        assert_eq!(off.resident_shards(2), Some(4));
        assert_eq!(recorder.lock().unwrap().summary().reshards, 0);
    }

    #[test]
    fn reshard_spec_reapplies_the_thread_budget() {
        // The oversubscription fix: budgets derive from the *raw* inner
        // spec and the per-worker core budget, not from the stale budgeted
        // spec of the old S.
        assert_eq!(reshard_spec("native", 4, 16), "sharded:4:native:4");
        assert_eq!(reshard_spec("native", 2, 16), "sharded:2:native:8");
        // Explicit operator thread counts pass through untouched.
        assert_eq!(reshard_spec("native:1", 4, 16), "sharded:4:native:1");
        assert_eq!(reshard_spec("functional", 2, 16), "sharded:2:functional");
    }

    #[test]
    fn trim_scratch_reclaims_idle_pool_bytes_and_reaccounts() {
        let mgr = ResidencyManager::new(
            ResidencyPolicy {
                scratch_idle: Some(Duration::from_millis(2)),
                ..Default::default()
            },
            ReshardPolicy::default(),
            None,
            None,
        );
        let recorder = Mutex::new(Recorder::default());
        let be = NativeBackend::new(1);
        let img = image(30);
        let Resolution::Shared(handle) = mgr.resolve(8, &img, &be, &recorder) else {
            panic!("native prepares sendable handles");
        };
        // Execute once so a scratch set is parked, then refresh the byte
        // accounting the way dispatch does after an execution.
        let n = 3;
        let b = vec![1.0f32; img.k * n];
        let mut c = vec![0.0f32; img.m * n];
        handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        mgr.note_bytes(8, handle.resident_bytes_now(), &recorder);
        let before = mgr.resident_bytes();
        std::thread::sleep(Duration::from_millis(10));
        let reclaimed = mgr.trim_scratch(&recorder);
        assert!(reclaimed > 0, "idle scratch must be reclaimed");
        assert!(
            mgr.resident_bytes() < before,
            "trim must re-account: {} -> {}",
            before,
            mgr.resident_bytes()
        );
        // Immediately after a sweep the rate limit suppresses the next one.
        assert_eq!(mgr.trim_scratch(&recorder), 0, "sweeps are rate-limited");
        // With trimming disabled, the manager never touches the handles.
        let off = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy::default(),
            None,
            None,
        );
        assert_eq!(off.trim_scratch(&recorder), 0);
    }

    #[test]
    fn reshard_replaces_bytes_accounting() {
        let mgr = ResidencyManager::new(
            ResidencyPolicy::default(),
            ReshardPolicy { imbalance_threshold: 1.5, window: 1 },
            Some(ReshardContext { inner_spec: "native:1".into(), budget: 4 }),
            None,
        );
        let recorder = Mutex::new(Recorder::default());
        let be = ShardedBackend::from_spec(8, "native:1").unwrap();
        mgr.resolve(3, &skewed_image(), &be, &recorder);
        let before = mgr.resident_bytes();
        assert!(before > 0);
        mgr.note_shards(3, &stats(8, 4.0), &recorder);
        assert_eq!(mgr.resident_shards(3), Some(4));
        let after = mgr.resident_bytes();
        assert!(after > 0);
        // Accounting stays consistent with the single resident entry.
        assert_eq!(mgr.len(), 1);
    }
}
