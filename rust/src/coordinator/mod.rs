//! L3 serving coordinator: an adaptive SpMM serving pipeline that turns
//! the HFlex accelerator into a service.
//!
//! Requests flow through four focused stages, each its own module:
//!
//! ```text
//!  submit()                                                response
//!     │                                                        ▲
//!     ▼                                                        │
//!  ┌─────────────┐   ┌─────────────┐   ┌──────────────┐        │
//!  │ 1 admission │──▶│ 2 batcher   │──▶│ 3 dispatch   │────────┘
//!  │  in-flight  │   │  merge      │   │  worker pool │
//!  │  gate, per- │   │  window,    │   │  thread      │   ┌──────────────┐
//!  │  image      │   │  shard-     │   │  budgets,    │◀─▶│ 4 residency  │
//!  │  quota,     │   │  aware      │   │  stage       │   │  byte-sized  │
//!  │  shedding   │   │  routing    │   │  timings,    │   │  cache of    │
//!  └─────────────┘   └─────────────┘   │  concurrent  │   │  shared Arc< │
//!        │                 │           │  &self exec  │   │  PreparedSpmm│
//!        │ admission       │ queue     └──────────────┘   │  > handles,  │
//!        ▼ span            ▼ span        │ batch/prepare/ │  re-shard on │
//!  ┌──────────────────────────────────── ▼ exec + root ─┐ │  skew        │
//!  │ telemetry sink (optional): one span tree / request │ └──────────────┘
//!  └────────────────────────────────────────────────────┘   │ backend.
//!                                                           ▼ prepare span
//! ```
//!
//! * [`admission`] — an in-flight gate sheds load at the front door
//!   instead of letting queues grow without bound; an optional per-image
//!   quota keeps one hot matrix from starving the rest.
//! * [`batcher`] — same-image requests merge by column concatenation
//!   within a bounded window (the paper's N/N0 amortization, applied
//!   across requests); small merged jobs are marked for shard-aware
//!   routing so a sharded handle skips shards owning no non-zeros.
//! * [`dispatch`] — the worker pool; composes thread budgets
//!   (workers × shards × engine threads ≤ cores), executes through shared
//!   `Arc<dyn PreparedSpmm>` handles *concurrently* (`&self` execution —
//!   no per-matrix lock, W workers on one hot matrix run W executes at
//!   once), and measures the per-stage latency breakdown plus the
//!   execution-concurrency high-water mark reported in
//!   [`metrics::Summary`].
//! * [`residency`] — prepared handles cached by resident **bytes** and
//!   cloned out to workers as plain `Arc<dyn PreparedSpmm + Send + Sync>`
//!   (the only locks left guard the cache map and the engines' scratch
//!   pools); rolling shard-imbalance triggers re-shard-on-skew (drop +
//!   re-prepare at a smaller S) without callers noticing.
//!
//! Every stage is instrumented twice over. Aggregates flow into
//! [`metrics::Recorder`]'s fixed-memory streaming histograms (per-stage,
//! per-backend, and per-image p50/p95/p99 in [`metrics::Summary`]). Per
//! request, an optional [`crate::telemetry::trace::TelemetrySink`] on
//! [`server::PipelineConfig`] receives a span tree — `admission`, `queue`,
//! `batch`, `prepare` (with a `backend.prepare` child on residency
//! misses), `exec`, and a closing `request` root — stamped from the same
//! `Instant`s as [`metrics::RequestTiming`], so traces and metrics always
//! reconcile.
//!
//! The public surface is the [`server::Server`] facade: `start`,
//! `start_backend`, `register`, `submit`, `call`, `shutdown` — plus
//! `start_with`/`start_backend_with` to set every stage policy through
//! [`server::PipelineConfig`].

pub mod admission;
pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod residency;
pub mod server;

pub use crate::backend::SpmmBackend;
pub use admission::AdmissionPolicy;
pub use batcher::BatchPolicy;
pub use residency::{ReshardPolicy, ResidencyPolicy};
pub use server::{
    ImageHandle, PipelineConfig, Server, SpmmRequest, SpmmResponse,
};
