//! L3 serving coordinator: an adaptive SpMM serving pipeline that turns
//! the HFlex accelerator into a service.
//!
//! Requests flow through four focused stages, each its own module:
//!
//! ```text
//!  ┌────────────────────────────────────────────────────────────────┐
//!  │ 0 network front door (optional): serve_net::FrontDoor          │
//!  │   `sextans serve --listen` — framed TCP, chunked register /    │
//!  │   column-block panel streaming, typed Shed frames, and a       │
//!  │   `net.frontend` span parenting each request's span tree       │
//!  └────────────────────────────────────────────────────────────────┘
//!     │ submit() per Await-able ticket
//!     ▼
//!  submit()                                                response
//!     │                                                        ▲
//!     ▼                                                        │
//!  ┌─────────────┐   ┌─────────────┐   ┌──────────────┐        │
//!  │ 1 admission │──▶│ 2 batcher   │──▶│ 3 dispatch   │────────┘
//!  │  in-flight  │   │  merge      │   │  worker pool │
//!  │  gate, per- │   │  window,    │   │  thread      │   ┌──────────────┐
//!  │  image      │   │  shard-     │   │  budgets,    │◀─▶│ 4 residency  │
//!  │  quota,     │   │  aware      │   │  stage       │   │  byte-sized  │
//!  │  shedding   │   │  routing    │   │  timings,    │   │  cache of    │
//!  └─────────────┘   └─────────────┘   │  concurrent  │   │  shared Arc< │
//!        │                 │           │  &self exec  │   │  PreparedSpmm│
//!        │ admission       │ queue     └──────────────┘   │  > handles,  │
//!        ▼ span            ▼ span        │ batch/prepare/ │  re-shard on │
//!  ┌──────────────────────────────────── ▼ exec + root ─┐ │  skew,       │
//!  │ telemetry sink (optional): one span tree / request │ │  scratch     │
//!  └────────────────────────────────────────────────────┘ │  trimming    │
//!                      ▲ net.rpc spans                     └──────────────┘
//!                      │                                     │ backend.
//!  ┌──────────────────────────────────────────────────────┐  ▼ prepare span
//!  │ 5 remote worker tier (optional): remote:<addr>[,...] │
//!  │   shards placed on `sextans worker` processes over   │
//!  │   the framed wire protocol — R-way replication,      │
//!  │   retry on replicas, re-place off dead workers       │
//!  └──────────────────────────────────────────────────────┘
//! ```
//!
//! * [`admission`] — an in-flight gate sheds load at the front door
//!   instead of letting queues grow without bound; an optional per-image
//!   quota keeps one hot matrix from starving the rest.
//! * [`batcher`] — same-image requests merge by column concatenation
//!   within a bounded window (the paper's N/N0 amortization, applied
//!   across requests); small merged jobs are marked for shard-aware
//!   routing so a sharded handle skips shards owning no non-zeros.
//! * [`dispatch`] — the worker pool; composes thread budgets
//!   (workers × shards × engine threads ≤ cores), executes through shared
//!   `Arc<dyn PreparedSpmm>` handles *concurrently* (`&self` execution —
//!   no per-matrix lock, W workers on one hot matrix run W executes at
//!   once), and measures the per-stage latency breakdown plus the
//!   execution-concurrency high-water mark reported in
//!   [`metrics::Summary`].
//! * [`residency`] — prepared handles cached by resident **bytes** and
//!   cloned out to workers as plain `Arc<dyn PreparedSpmm + Send + Sync>`
//!   (the only locks left guard the cache map and the engines' scratch
//!   pools); rolling shard-imbalance triggers re-shard-on-skew (drop +
//!   re-prepare at a smaller S) without callers noticing, and an optional
//!   scratch-idle timeout trims pooled scratch that sat parked past its
//!   high-water mark, shedding a concurrency burst's footprint.
//! * **remote worker tier** (optional) — a `remote:<addr>[,addr...]`
//!   backend spec swaps the in-process engine for a fleet of
//!   `sextans worker` processes reached over [`crate::net`]'s framed wire
//!   protocol. The dispatch stage is oblivious: the handle it executes is
//!   a [`crate::net::PreparedRemote`] that places shards across the fleet
//!   (R-way replicated), retries failures on replicas, re-places shards
//!   off dead workers, and reports placement/retry/re-place counters that
//!   land in [`metrics::Summary`] and as `net.rpc` child spans under each
//!   request's `exec` span.
//! * **network front door** (optional) — [`crate::serve_net`] puts a
//!   socket in front of `submit()`: `sextans serve --listen` accepts
//!   framed TCP clients, stages chunked image registration and
//!   column-block panel uploads, and forwards each completed submit into
//!   stage 1 with a `net.frontend` span pushed as the thread-local
//!   parent, so admission sheds come back to the client as typed `Shed`
//!   frames and the span tree covers socket to executor.
//!
//! Every stage is instrumented twice over. Aggregates flow into
//! [`metrics::Recorder`]'s fixed-memory streaming histograms (per-stage,
//! per-backend, and per-image p50/p95/p99 in [`metrics::Summary`]). Per
//! request, an optional [`crate::telemetry::trace::TelemetrySink`] on
//! [`server::PipelineConfig`] receives a span tree — `admission`, `queue`,
//! `batch`, `prepare` (with a `backend.prepare` child on residency
//! misses), `exec`, and a closing `request` root — stamped from the same
//! `Instant`s as [`metrics::RequestTiming`], so traces and metrics always
//! reconcile.
//!
//! The public surface is the [`server::Server`] facade: `start`,
//! `start_backend`, `register`, `submit`, `call`, `shutdown` — plus
//! `start_with`/`start_backend_with` to set every stage policy through
//! [`server::PipelineConfig`].

pub mod admission;
pub mod batcher;
pub mod dispatch;
pub mod metrics;
pub mod residency;
pub mod server;

pub use crate::backend::SpmmBackend;
pub use admission::AdmissionPolicy;
pub use batcher::BatchPolicy;
pub use residency::{ReshardPolicy, ResidencyPolicy};
pub use server::{
    ImageHandle, PipelineConfig, RejectKind, Server, SpmmRequest, SpmmResponse,
};
