//! L3 serving coordinator: request router, dynamic batcher, worker pool,
//! serving metrics — the systems wrapper that turns the HFlex accelerator
//! into a service.

pub mod metrics;
pub mod server;

pub use server::{
    BatchPolicy, Executor, FunctionalExecutor, ImageHandle, Server, SpmmRequest, SpmmResponse,
};
