//! L3 serving coordinator: request router, dynamic batcher, worker pool,
//! serving metrics — the systems wrapper that turns the HFlex accelerator
//! into a service.
//!
//! Execution is pluggable: workers run any [`crate::backend::SpmmBackend`]
//! (native multi-threaded engine by default), constructed per worker thread
//! either via a factory closure ([`Server::start`]) or by registry name
//! ([`Server::start_backend`]). Each worker keeps an MRU cache of
//! [`crate::backend::PreparedSpmm`] handles keyed on the registered image,
//! so repeated requests against one matrix prepare it once per worker —
//! the prepare hit rate, wall time, and resident bytes are part of the
//! serving [`metrics::Summary`].

pub mod metrics;
pub mod server;

pub use crate::backend::SpmmBackend;
pub use server::{BatchPolicy, ImageHandle, Server, SpmmRequest, SpmmResponse};
