//! Stage 1 — **admission**: every request passes a backpressure gate
//! before it may enter the batching queue.
//!
//! The gate is a lock-free in-flight counter: a submit beyond
//! [`AdmissionPolicy::max_in_flight`] is rejected immediately with an error
//! response instead of growing the queue without bound — load shedding at
//! the front door, so a traffic spike degrades into fast rejections rather
//! than unbounded memory growth and timeout cascades deep in the pipeline.
//! Rejections are counted in the serving summary
//! ([`super::metrics::Summary::rejected`]).
//!
//! **Per-image fairness** ([`AdmissionPolicy::per_image_quota`], off by
//! default): with shared handles executing concurrently, one hot matrix
//! can fill the whole global gate and starve every other registered image.
//! The quota bounds how many in-flight requests any single image may hold;
//! a request over its image's quota is shed even when the global gate has
//! room, and the shed is attributed to that image in
//! [`super::metrics::Summary::image_sheds`]. The per-image counts live
//! under a tiny mutex that is only touched when the quota is enabled — the
//! quota-off path stays a single lock-free CAS.
//!
//! One admission slot is held from submit until the response for that
//! request is sent (dispatch releases it per segment), so the bound covers
//! the whole pipeline: queued, batching, and executing requests all count.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Backpressure policy for the admission stage.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Maximum requests in flight (admitted but not yet responded to). A
    /// submit beyond this is rejected immediately with an error response.
    pub max_in_flight: usize,
    /// Maximum in-flight requests any one registered image may hold; `0`
    /// (the default) disables the per-image quota. Keeps one hot matrix
    /// from starving the rest of the gate.
    pub per_image_quota: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { max_in_flight: 4096, per_image_quota: 0 }
    }
}

/// Outcome of one admission attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// A slot was reserved; the request may enter the pipeline.
    Admitted,
    /// The global in-flight bound is full — shed.
    Full,
    /// The global gate had room, but this image is at its per-image
    /// quota — shed, attributed to the image.
    ImageQuota,
}

/// The admission gate: an in-flight counter enforcing [`AdmissionPolicy`],
/// shared by submitting callers (admit) and dispatch workers (release).
#[derive(Debug)]
pub struct AdmissionGate {
    policy: AdmissionPolicy,
    in_flight: AtomicUsize,
    /// In-flight count per image id; consulted only when
    /// `policy.per_image_quota > 0` (entries are dropped at zero, so the
    /// map stays as small as the set of currently-active images, and
    /// admit/release stay O(1) however many images are live).
    per_image: Mutex<HashMap<u64, usize>>,
}

impl AdmissionGate {
    /// Build a gate enforcing `policy`.
    pub fn new(policy: AdmissionPolicy) -> AdmissionGate {
        AdmissionGate {
            policy,
            in_flight: AtomicUsize::new(0),
            per_image: Mutex::new(HashMap::new()),
        }
    }

    /// Try to admit one request for `image_id`: [`Admit::Admitted`]
    /// reserves an in-flight slot (global, plus the image's when the quota
    /// is on); the two shed variants reserve nothing once they return.
    ///
    /// The per-image quota is checked **before** the global slot is
    /// reserved: an over-quota hot image's rejected burst then never
    /// transiently occupies global capacity, so it cannot spuriously
    /// [`Admit::Full`]-shed other images — the transient reservation of a
    /// doomed attempt (quota slot rolled back on a full global gate)
    /// harms only the image that made it.
    pub fn try_admit(&self, image_id: u64) -> Admit {
        if self.policy.per_image_quota > 0 {
            let mut per_image = self.per_image.lock().unwrap();
            // A fresh entry starts at 0 < quota (quota >= 1 here), so
            // this never parks a dead zero-count entry in the map.
            let count = per_image.entry(image_id).or_insert(0);
            if *count >= self.policy.per_image_quota {
                return Admit::ImageQuota;
            }
            *count += 1;
        }
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.policy.max_in_flight {
                // Roll back this image's reservation: the request was
                // shed by the global bound, not admitted.
                if self.policy.per_image_quota > 0 {
                    self.release_image(image_id);
                }
                return Admit::Full;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Admit::Admitted,
                Err(now) => cur = now,
            }
        }
    }

    /// Drop one reservation from `image_id`'s quota count (entries are
    /// removed at zero so the map tracks only live images).
    fn release_image(&self, image_id: u64) {
        let mut per_image = self.per_image.lock().unwrap();
        let drained = per_image.get_mut(&image_id).map(|count| {
            *count -= 1;
            *count == 0
        });
        if drained == Some(true) {
            per_image.remove(&image_id);
        }
    }

    /// Release one admitted request for `image_id` (called exactly once
    /// per response).
    pub fn release(&self, image_id: u64) {
        if self.policy.per_image_quota > 0 {
            self.release_image(image_id);
        }
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Requests currently admitted and not yet responded to.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// In-flight requests currently held by `image_id` (0 when the quota
    /// is disabled — counts are only tracked while it is on).
    pub fn image_in_flight(&self, image_id: u64) -> usize {
        self.per_image.lock().unwrap().get(&image_id).copied().unwrap_or(0)
    }

    /// The policy this gate enforces.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(max_in_flight: usize) -> AdmissionGate {
        AdmissionGate::new(AdmissionPolicy { max_in_flight, per_image_quota: 0 })
    }

    #[test]
    fn admits_up_to_the_bound_then_rejects() {
        let gate = gate(3);
        assert_eq!(gate.try_admit(1), Admit::Admitted);
        assert_eq!(gate.try_admit(1), Admit::Admitted);
        assert_eq!(gate.try_admit(2), Admit::Admitted);
        assert_eq!(gate.in_flight(), 3);
        assert_eq!(gate.try_admit(3), Admit::Full, "fourth request must be shed");
        assert_eq!(gate.in_flight(), 3, "a rejected request holds no slot");
    }

    #[test]
    fn release_reopens_the_gate() {
        let gate = gate(1);
        assert_eq!(gate.try_admit(1), Admit::Admitted);
        assert_eq!(gate.try_admit(1), Admit::Full);
        gate.release(1);
        assert_eq!(gate.in_flight(), 0);
        assert_eq!(gate.try_admit(1), Admit::Admitted);
    }

    #[test]
    fn zero_depth_rejects_everything() {
        let gate = gate(0);
        assert_eq!(gate.try_admit(1), Admit::Full);
        assert_eq!(gate.in_flight(), 0);
    }

    #[test]
    fn per_image_quota_sheds_the_hot_image_only() {
        let gate = AdmissionGate::new(AdmissionPolicy {
            max_in_flight: 10,
            per_image_quota: 2,
        });
        assert_eq!(gate.try_admit(7), Admit::Admitted);
        assert_eq!(gate.try_admit(7), Admit::Admitted);
        // The hot image is at quota; the global gate still has room.
        assert_eq!(gate.try_admit(7), Admit::ImageQuota);
        assert_eq!(gate.in_flight(), 2, "a quota shed returns its global slot");
        assert_eq!(gate.image_in_flight(7), 2);
        // Other images are unaffected — that is the fairness point.
        assert_eq!(gate.try_admit(8), Admit::Admitted);
        assert_eq!(gate.image_in_flight(8), 1);
        // Releasing the hot image reopens its quota.
        gate.release(7);
        assert_eq!(gate.try_admit(7), Admit::Admitted);
        assert_eq!(gate.in_flight(), 3);
    }

    #[test]
    fn quota_releases_drop_empty_image_entries() {
        let gate = AdmissionGate::new(AdmissionPolicy {
            max_in_flight: 4,
            per_image_quota: 4,
        });
        assert_eq!(gate.try_admit(1), Admit::Admitted);
        gate.release(1);
        assert_eq!(gate.image_in_flight(1), 0);
        assert_eq!(gate.in_flight(), 0);
        assert!(gate.per_image.lock().unwrap().is_empty(), "zero counts are dropped");
    }

    #[test]
    fn global_bound_still_wins_over_quota_headroom() {
        let gate = AdmissionGate::new(AdmissionPolicy {
            max_in_flight: 1,
            per_image_quota: 5,
        });
        assert_eq!(gate.try_admit(1), Admit::Admitted);
        assert_eq!(gate.try_admit(2), Admit::Full, "quota headroom cannot bypass the bound");
        // The Full shed rolled its per-image reservation back.
        assert_eq!(gate.image_in_flight(2), 0, "a Full shed holds no quota slot");
    }

    #[test]
    fn over_quota_bursts_never_occupy_global_slots() {
        // The fairness point of quota-before-global ordering: a hot image
        // hammering past its quota is shed without ever touching the
        // global counter, so other images see full capacity.
        let gate = AdmissionGate::new(AdmissionPolicy {
            max_in_flight: 2,
            per_image_quota: 1,
        });
        assert_eq!(gate.try_admit(7), Admit::Admitted);
        for _ in 0..50 {
            assert_eq!(gate.try_admit(7), Admit::ImageQuota);
            assert_eq!(gate.in_flight(), 1, "quota sheds must not touch the global gate");
        }
        assert_eq!(gate.try_admit(8), Admit::Admitted, "other images keep their capacity");
    }

    #[test]
    fn concurrent_admission_never_exceeds_bound() {
        use std::sync::Arc;
        let gate = Arc::new(gate(8));
        let admitted: usize = std::thread::scope(|scope| {
            (0..4)
                .map(|t| {
                    let gate = Arc::clone(&gate);
                    scope.spawn(move || {
                        (0..10).filter(|_| gate.try_admit(t) == Admit::Admitted).count()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(admitted, 8, "exactly max_in_flight across all threads");
        assert_eq!(gate.in_flight(), 8);
    }

    #[test]
    fn concurrent_quota_never_exceeds_per_image_bound() {
        use std::sync::Arc;
        let gate = Arc::new(AdmissionGate::new(AdmissionPolicy {
            max_in_flight: 64,
            per_image_quota: 3,
        }));
        let admitted: usize = std::thread::scope(|scope| {
            (0..4)
                .map(|_| {
                    let gate = Arc::clone(&gate);
                    scope.spawn(move || {
                        (0..8).filter(|_| gate.try_admit(42) == Admit::Admitted).count()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(admitted, 3, "quota holds under contention");
        assert_eq!(gate.image_in_flight(42), 3);
    }
}
