//! Stage 1 — **admission**: every request passes a backpressure gate
//! before it may enter the batching queue.
//!
//! The gate is a lock-free in-flight counter: a submit beyond
//! [`AdmissionPolicy::max_in_flight`] is rejected immediately with an error
//! response instead of growing the queue without bound — load shedding at
//! the front door, so a traffic spike degrades into fast rejections rather
//! than unbounded memory growth and timeout cascades deep in the pipeline.
//! Rejections are counted in the serving summary
//! ([`super::metrics::Summary::rejected`]).
//!
//! One admission slot is held from submit until the response for that
//! request is sent (dispatch releases it per segment), so the bound covers
//! the whole pipeline: queued, batching, and executing requests all count.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Backpressure policy for the admission stage.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Maximum requests in flight (admitted but not yet responded to). A
    /// submit beyond this is rejected immediately with an error response.
    pub max_in_flight: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { max_in_flight: 4096 }
    }
}

/// The admission gate: an in-flight counter enforcing [`AdmissionPolicy`],
/// shared by submitting callers (admit) and dispatch workers (release).
#[derive(Debug)]
pub struct AdmissionGate {
    policy: AdmissionPolicy,
    in_flight: AtomicUsize,
}

impl AdmissionGate {
    /// Build a gate enforcing `policy`.
    pub fn new(policy: AdmissionPolicy) -> AdmissionGate {
        AdmissionGate { policy, in_flight: AtomicUsize::new(0) }
    }

    /// Try to admit one request: `true` reserves an in-flight slot, `false`
    /// means the pipeline is full and the request must be rejected.
    pub fn try_admit(&self) -> bool {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.policy.max_in_flight {
                return false;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Release one admitted request (called exactly once per response).
    pub fn release(&self) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Requests currently admitted and not yet responded to.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// The policy this gate enforces.
    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_the_bound_then_rejects() {
        let gate = AdmissionGate::new(AdmissionPolicy { max_in_flight: 3 });
        assert!(gate.try_admit());
        assert!(gate.try_admit());
        assert!(gate.try_admit());
        assert_eq!(gate.in_flight(), 3);
        assert!(!gate.try_admit(), "fourth request must be shed");
        assert_eq!(gate.in_flight(), 3, "a rejected request holds no slot");
    }

    #[test]
    fn release_reopens_the_gate() {
        let gate = AdmissionGate::new(AdmissionPolicy { max_in_flight: 1 });
        assert!(gate.try_admit());
        assert!(!gate.try_admit());
        gate.release();
        assert_eq!(gate.in_flight(), 0);
        assert!(gate.try_admit());
    }

    #[test]
    fn zero_depth_rejects_everything() {
        let gate = AdmissionGate::new(AdmissionPolicy { max_in_flight: 0 });
        assert!(!gate.try_admit());
        assert_eq!(gate.in_flight(), 0);
    }

    #[test]
    fn concurrent_admission_never_exceeds_bound() {
        use std::sync::Arc;
        let gate = Arc::new(AdmissionGate::new(AdmissionPolicy { max_in_flight: 8 }));
        let admitted: usize = std::thread::scope(|scope| {
            (0..4)
                .map(|_| {
                    let gate = Arc::clone(&gate);
                    scope.spawn(move || (0..10).filter(|_| gate.try_admit()).count())
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(admitted, 8, "exactly max_in_flight across all threads");
        assert_eq!(gate.in_flight(), 8);
    }
}
