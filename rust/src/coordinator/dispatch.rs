//! Stage 3 — **dispatch**: the worker pool that executes merged jobs
//! against resident handles and splits results back per request.
//!
//! Workers are std::thread; the backend factory is called once per worker
//! thread. Handle resolution goes through the shared residency stage
//! ([`super::residency`]) first: resolved handles are plain
//! `Arc<dyn PreparedSpmm + Send + Sync>` clones, and execution goes
//! through `&self` — W workers serving one hot matrix run W executes
//! *concurrently* (the engines draw per-call scratch from internal
//! pools), with no per-matrix lock anywhere on the path. Backends whose
//! handles cannot cross threads (the real PJRT engine) fall back to a
//! per-worker thread-local MRU cache — the same residency discipline,
//! scoped to one thread.
//!
//! Dispatch also owns the **thread-budget composition**: the machine's
//! cores are divided across the worker threads
//! ([`per_worker_budget`]), each worker's share is applied to auto-sized
//! backend specs, and the sharded composite divides its share per shard —
//! so workers × shards × engine threads never oversubscribes the CPU.
//! After a runtime re-shard the residency stage re-derives the same
//! composition for the new S ([`super::residency::reshard_spec`]).
//!
//! Per-stage timings measured here (prepare wait, execute) join the
//! batcher's timestamps (queue wait, batch wait) in each response's
//! [`RequestTiming`]. With the per-matrix mutex gone, the `exec` stage is
//! *pure engine time* — the lock wait that used to be folded into it no
//! longer exists, so a worker-side stall can only appear as queue/batch
//! wait (upstream of pickup) or as prepare (residency resolution). The
//! [`ConcurrencyGauge`] threaded through the workers records how many
//! executions actually overlap ([`super::metrics::Summary`]'s
//! `exec_concurrency_peak`), making the lock removal observable.
//!
//! When a [`crate::telemetry::trace::TelemetrySink`] is configured, each
//! traced request additionally gets `batch`/`prepare`/`exec` spans and a
//! closing `request` root span. The spans are stamped from the *same*
//! `Instant`s that populate [`RequestTiming`], so a span tree's stage
//! durations reconcile with the metrics exactly — there are no second
//! clock reads to drift.

use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::AdmissionGate;
use super::batcher::{MergedJob, Segment};
use super::metrics::{ConcurrencyGauge, DeadlineStage, Recorder, RequestTiming};
use super::residency::{Resolution, ResidencyManager, PREPARED_CACHE_ENTRIES};
use super::server::{RejectKind, SpmmResponse};
use crate::arch::simulator::problem_flops;
use crate::backend::{ExecutionReport, PreparedSpmm, RemoteStats, SpmmBackend};
use crate::shard::ShardRunStats;
use crate::telemetry::trace::{
    instant_ns, next_span_id, now_ns, push_span_context, SpanRecord, TelemetrySink,
};

/// Per-worker core budget: the machine's cores divided across `n_workers`
/// threads, at least one — the first factor of the workers × shards ×
/// engine-threads composition.
pub fn per_worker_budget(n_workers: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.div_ceil(n_workers.max(1)).max(1)
}

/// Spawn the worker pool: each worker constructs its own backend from the
/// factory and loops on the shared job channel until it disconnects.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_workers<F>(
    n_workers: usize,
    factory: Arc<F>,
    job_rx: Arc<Mutex<Receiver<MergedJob>>>,
    recorder: Arc<Mutex<Recorder>>,
    residency: Arc<ResidencyManager>,
    gate: Arc<AdmissionGate>,
    exec_gauge: Arc<ConcurrencyGauge>,
    sink: Option<Arc<dyn TelemetrySink>>,
) -> Vec<JoinHandle<()>>
where
    F: Fn(usize) -> Box<dyn SpmmBackend> + Send + Sync + 'static,
{
    (0..n_workers.max(1))
        .map(|w| {
            let job_rx = Arc::clone(&job_rx);
            let recorder = Arc::clone(&recorder);
            let residency = Arc::clone(&residency);
            let gate = Arc::clone(&gate);
            let factory = Arc::clone(&factory);
            let exec_gauge = Arc::clone(&exec_gauge);
            let sink = sink.clone();
            std::thread::spawn(move || {
                let exec = factory(w);
                worker_loop(&*exec, job_rx, recorder, residency, gate, exec_gauge, sink);
            })
        })
        .collect()
}

/// Run one merged job on a resolved handle: the routed path lets a sharded
/// handle skip shards owning no non-zeros. Returns *this call's* execution
/// report — taking skip counts and shard stats by value from the report
/// (instead of polling `shard_stats()` afterwards) keeps attribution
/// correct when several workers execute the same handle concurrently.
fn run_job(
    handle: &dyn PreparedSpmm,
    job: &mut MergedJob,
) -> Result<ExecutionReport, crate::backend::BackendError> {
    if job.routed {
        handle.execute_routed_with_report(
            &job.b_cat,
            &mut job.c_cat,
            job.n_total,
            job.alpha,
            job.beta,
        )
    } else {
        handle.execute_with_report(&job.b_cat, &mut job.c_cat, job.n_total, job.alpha, job.beta)
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    backend: &dyn SpmmBackend,
    job_rx: Arc<Mutex<Receiver<MergedJob>>>,
    recorder: Arc<Mutex<Recorder>>,
    residency: Arc<ResidencyManager>,
    gate: Arc<AdmissionGate>,
    exec_gauge: Arc<ConcurrencyGauge>,
    sink: Option<Arc<dyn TelemetrySink>>,
) {
    let backend_name = backend.name();
    // Fallback cache for thread-local handles, MRU-first, keyed on
    // ImageHandle id (entry-bounded; the shared cache is byte-sized).
    let mut local: Vec<(u64, Box<dyn PreparedSpmm>)> = Vec::new();
    loop {
        let job = {
            let rx = job_rx.lock().unwrap();
            rx.recv()
        };
        let Ok(mut job) = job else { break };
        let picked = Instant::now();

        // Deadline re-check at pickup: peel off segments that already
        // expired in the dispatch queue and answer them with a typed
        // reject instead of paying an execute. Column offsets of the
        // survivors still index the merged buffers, so a partially
        // expired job executes unchanged and only live segments split
        // results back out.
        let (live, expired): (Vec<Segment>, Vec<Segment>) = job
            .segments
            .drain(..)
            .partition(|s| s.deadline.map_or(true, |d| picked < d));
        job.segments = live;
        for seg in expired {
            recorder.lock().unwrap().record_deadline(DeadlineStage::Dispatch);
            gate.release(job.image.id);
            let _ = seg.respond.send(SpmmResponse {
                c: Vec::new(),
                timing: RequestTiming {
                    queue: seg.admitted.duration_since(seg.submitted),
                    batch: picked.duration_since(seg.admitted),
                    prepare: std::time::Duration::ZERO,
                    exec: std::time::Duration::ZERO,
                    flops: 0,
                    backend: "deadline",
                    image: job.image.id,
                },
                error: Some("deadline exceeded at dispatch pickup".to_string()),
                rejected: Some(RejectKind::DeadlineExceeded),
            });
        }
        if job.segments.is_empty() {
            continue;
        }
        // When every surviving segment carries a deadline, propagate the
        // loosest one to fleet RPCs below the execute (a replica chain
        // must not outlive the last caller still waiting on it). A mixed
        // batch propagates nothing: an undeadlined segment is entitled to
        // the full execute.
        let fleet_deadline: Option<Instant> =
            if job.segments.iter().all(|s| s.deadline.is_some()) {
                job.segments.iter().filter_map(|s| s.deadline).max()
            } else {
                None
            };

        // Pre-allocate the first traced segment's `prepare` span id so a
        // residency miss can parent its `backend.prepare` span under it
        // before the `prepare` span itself is emitted below.
        let prepare_span = if sink.is_some() {
            job.segments
                .iter()
                .find_map(|s| s.trace)
                .map(|ctx| (ctx.trace_id, next_span_id()))
        } else {
            None
        };
        // Likewise pre-allocate the `exec` span id and install it as the
        // thread's span context for the duration of the execution, so
        // engines that fan out over the network (the `remote:` backend)
        // can parent their `net.rpc` spans under this request's exec span.
        let exec_span = if sink.is_some() {
            job.segments
                .iter()
                .find_map(|s| s.trace)
                .map(|ctx| (ctx.trace_id, next_span_id()))
        } else {
            None
        };

        // Stage boundary: residency resolution (cache hit or prepare).
        let t_prepare = Instant::now();
        let resolution = residency.resolve_traced(
            job.image.id,
            &job.image.image,
            backend,
            &recorder,
            prepare_span,
        );
        let mut skipped = 0usize;
        let mut stats: Option<ShardRunStats> = None;
        let mut remote_stats: Option<RemoteStats> = None;
        let mut resident_now: Option<u64> = None;
        let (prepare_end, t_exec, exec_end, error) = match resolution {
            Resolution::Shared(shared) => {
                let prepare_end = Instant::now();
                // Execute straight through the shared handle — `&self`,
                // no lock, concurrent with every other worker on the same
                // matrix. The gauge counts overlapping executions so the
                // summary can report the realized concurrency.
                let t_exec = Instant::now();
                let r = {
                    let _in_exec = exec_gauge.enter();
                    let _span_ctx =
                        exec_span.map(|(trace_id, id)| push_span_context(trace_id, id));
                    let _deadline_ctx =
                        fleet_deadline.map(crate::net::remote::push_call_deadline);
                    run_job(&*shared, &mut job)
                };
                let exec_end = Instant::now();
                let error = match r {
                    Ok(report) => {
                        skipped = report.skipped;
                        stats = report.shard_stats;
                        remote_stats = report.remote;
                        // Scratch pools may have grown under concurrency;
                        // refresh the shared cache's byte accounting from
                        // the handle's live footprint after responses.
                        resident_now = Some(shared.resident_bytes_now());
                        None
                    }
                    Err(e) => Some(e.to_string()),
                };
                (prepare_end, t_exec, exec_end, error)
            }
            Resolution::ThreadLocal => {
                // Resolve in the worker-local fallback cache; a miss pays
                // the backend's build path once per worker.
                let resolved: Result<(), String> =
                    match local.iter().position(|(id, _)| *id == job.image.id) {
                        Some(0) => {
                            recorder.lock().unwrap().record_prepare_hit();
                            Ok(())
                        }
                        Some(i) => {
                            let entry = local.remove(i);
                            local.insert(0, entry);
                            recorder.lock().unwrap().record_prepare_hit();
                            Ok(())
                        }
                        None => match backend.prepare(Arc::clone(&job.image.image)) {
                            Ok(handle) => {
                                recorder
                                    .lock()
                                    .unwrap()
                                    .record_prepare(&handle.prepare_cost());
                                local.insert(0, (job.image.id, handle));
                                local.truncate(PREPARED_CACHE_ENTRIES);
                                Ok(())
                            }
                            Err(e) => Err(e.to_string()),
                        },
                    };
                let prepare_end = Instant::now();
                let t_exec = Instant::now();
                let error = match resolved {
                    Ok(()) => {
                        let handle = &*local[0].1;
                        let r = {
                            let _in_exec = exec_gauge.enter();
                            let _span_ctx = exec_span
                                .map(|(trace_id, id)| push_span_context(trace_id, id));
                            let _deadline_ctx =
                                fleet_deadline.map(crate::net::remote::push_call_deadline);
                            run_job(handle, &mut job)
                        };
                        match r {
                            Ok(report) => {
                                skipped = report.skipped;
                                stats = report.shard_stats;
                                remote_stats = report.remote;
                                None
                            }
                            Err(e) => Some(e.to_string()),
                        }
                    }
                    Err(e) => Some(e),
                };
                let exec_end = Instant::now();
                (prepare_end, t_exec, exec_end, error)
            }
        };
        let prepare_dur = prepare_end.duration_since(t_prepare);
        let exec_dur = exec_end.duration_since(t_exec);
        if error.is_none() {
            if let Some(ref s) = stats {
                // Routed accounting only means something on a handle that
                // actually has shards to skip; the default execute_routed
                // passthrough on single-unit engines reports no stats.
                if job.routed {
                    recorder.lock().unwrap().record_routed(skipped);
                }
                recorder.lock().unwrap().record_shards(s);
            }
            if let Some(ref rs) = remote_stats {
                recorder.lock().unwrap().record_remote(rs);
            }
        }
        // Split C back per request and respond with per-stage timings —
        // before feeding the skew window, so a triggered rebuild never
        // delays responses whose results are already computed.
        let m = job.image.image.m;
        let nnz = job.image.image.nnz;
        for seg in job.segments {
            let mut c = vec![0f32; m * seg.n];
            if error.is_none() {
                for row in 0..m {
                    c[row * seg.n..(row + 1) * seg.n].copy_from_slice(
                        &job.c_cat[row * job.n_total + seg.col_off
                            ..row * job.n_total + seg.col_off + seg.n],
                    );
                }
            }
            let timing = RequestTiming {
                queue: seg.admitted.duration_since(seg.submitted),
                batch: picked.duration_since(seg.admitted),
                prepare: prepare_dur,
                exec: exec_dur,
                flops: problem_flops(nnz, m, seg.n),
                backend: backend_name,
                image: job.image.id,
            };
            recorder.lock().unwrap().record(timing);
            let _ = seg.respond.send(SpmmResponse {
                c,
                timing,
                error: error.clone(),
                rejected: None,
            });
            gate.release(job.image.id);
            // Stage spans share the `Instant`s the timing above was built
            // from, so the tree's durations reconcile with it exactly. The
            // root `request` span closes last, after the response is sent.
            if let (Some(sink), Some(ctx)) = (sink.as_deref(), seg.trace) {
                sink.emit(SpanRecord::from_instants(
                    ctx.trace_id,
                    Some(ctx.root_id),
                    "batch",
                    seg.admitted,
                    picked,
                ));
                let prepare_id = match prepare_span {
                    Some((t, id)) if t == ctx.trace_id => id,
                    _ => next_span_id(),
                };
                sink.emit(SpanRecord {
                    trace_id: ctx.trace_id,
                    span_id: prepare_id,
                    parent_id: Some(ctx.root_id),
                    name: "prepare",
                    start_ns: instant_ns(t_prepare),
                    end_ns: instant_ns(prepare_end),
                    tags: Vec::new(),
                });
                let exec_id = match exec_span {
                    Some((t, id)) if t == ctx.trace_id => id,
                    _ => next_span_id(),
                };
                sink.emit(
                    SpanRecord {
                        trace_id: ctx.trace_id,
                        span_id: exec_id,
                        parent_id: Some(ctx.root_id),
                        name: "exec",
                        start_ns: instant_ns(t_exec),
                        end_ns: instant_ns(exec_end),
                        tags: Vec::new(),
                    }
                    .tag("backend", backend_name),
                );
                let mut root = SpanRecord {
                    trace_id: ctx.trace_id,
                    span_id: ctx.root_id,
                    parent_id: ctx.root_parent,
                    name: "request",
                    start_ns: instant_ns(seg.submitted),
                    end_ns: now_ns(),
                    tags: Vec::new(),
                }
                .tag("backend", backend_name)
                .tag("image", job.image.id.to_string());
                if let Some(e) = &error {
                    root = root.tag("error", e.clone());
                }
                sink.emit(root);
            }
        }
        if error.is_none() {
            // Refresh the shared cache's byte accounting with the handle's
            // live footprint (scratch pools grow under concurrency), then
            // feed the re-shard-on-skew window last: a rebuild it triggers
            // is paid here, after this job's callers have their answers.
            if let Some(bytes) = resident_now {
                residency.note_bytes(job.image.id, bytes, &recorder);
            }
            if let Some(ref s) = stats {
                residency.note_shards(job.image.id, s, &recorder);
            }
            // Sweep idle pooled scratch (rate-limited inside; a no-op
            // unless the residency policy sets a scratch-idle timeout).
            let _ = residency.trim_scratch(&recorder);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_worker_budget_divides_cores() {
        let cores =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(per_worker_budget(1), cores);
        assert!(per_worker_budget(cores * 4) >= 1);
        // Zero workers is clamped, never a division by zero.
        assert_eq!(per_worker_budget(0), cores);
        // Shares cover the machine: budget * workers >= cores.
        for w in 1..=8 {
            assert!(per_worker_budget(w) * w >= cores, "workers = {w}");
        }
    }
}
