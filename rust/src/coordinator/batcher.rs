//! Stage 2 — **batching**: admitted requests are merged within a bounded
//! window into column-concatenated jobs, and small jobs are marked for
//! shard-aware routing.
//!
//! **Dynamic batching** exploits SpMM's structure: two requests against the
//! same preprocessed A image with matching (α, β) are *column-concatenated*
//! into a single SpMM with N = N₁ + N₂ — the accelerator's per-window costs
//! (B stream, C init, pointers) amortize across the batch exactly as the
//! paper's N/N0 loop amortizes them across columns. The batcher groups by
//! (image id, α bits, β bits) within [`BatchPolicy::window`], dispatches
//! merged jobs to the worker pool, and the dispatch stage splits C back per
//! request.
//!
//! **Shard-aware routing**: a merged job whose total column count stays at
//! or below [`BatchPolicy::route_columns`] is dispatched as *routed* — a
//! sharded execution handle then runs it only on the shards whose row sets
//! contain non-zeros, skipping empty shards entirely (their rows receive
//! the exact `beta * C` update host-side). For small N the per-shard
//! fan-out overhead is comparable to the useful work, so skipping shards
//! that would compute nothing is pure latency win; results are
//! bit-identical to the unrouted path.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::admission::AdmissionGate;
use super::metrics::{DeadlineStage, Recorder, RequestTiming};
use super::server::{ImageHandle, RejectKind, SpmmRequest, SpmmResponse, TraceCtx};
use crate::telemetry::trace::{SpanRecord, TelemetrySink};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max total columns per merged job (paper sweeps N up to 512).
    pub max_columns: usize,
    /// How long the batcher waits to fill a batch.
    pub window: Duration,
    /// Shard-aware routing threshold: a merged job with at most this many
    /// total columns is dispatched *routed*, letting a sharded handle skip
    /// shards that own no non-zeros. `0` disables routing.
    pub route_columns: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_columns: 512,
            window: Duration::from_millis(2),
            route_columns: 8,
        }
    }
}

/// A request waiting in the batcher, with its stage timestamps.
pub(crate) struct PendingReq {
    pub(crate) req: SpmmRequest,
    pub(crate) respond: Sender<SpmmResponse>,
    /// When the caller submitted (the queue stage starts here).
    pub(crate) submitted: Instant,
    /// When the batcher admitted it to a merge group (the batch stage
    /// starts here).
    pub(crate) admitted: Instant,
    /// Telemetry ids, when the pipeline has a sink configured.
    pub(crate) trace: Option<TraceCtx>,
}

/// Messages from the server facade into the batching stage.
pub(crate) enum Msg {
    /// One request with its response channel, submit timestamp, and
    /// telemetry trace ids (when a sink is configured).
    Request(SpmmRequest, Sender<SpmmResponse>, Instant, Option<TraceCtx>),
    /// Drain pending groups and stop.
    Shutdown,
}

/// One request's slice of a merged job.
pub(crate) struct Segment {
    pub(crate) n: usize,
    pub(crate) col_off: usize,
    pub(crate) submitted: Instant,
    pub(crate) admitted: Instant,
    pub(crate) respond: Sender<SpmmResponse>,
    pub(crate) trace: Option<TraceCtx>,
    /// The request's absolute deadline, re-checked at dispatch pickup.
    pub(crate) deadline: Option<Instant>,
}

/// A batch-merged job handed to the dispatch stage.
pub(crate) struct MergedJob {
    pub(crate) image: ImageHandle,
    pub(crate) alpha: f32,
    pub(crate) beta: f32,
    pub(crate) b_cat: Vec<f32>,
    pub(crate) c_cat: Vec<f32>,
    pub(crate) n_total: usize,
    /// Dispatch through the shard-aware routed path (small-N job).
    pub(crate) routed: bool,
    pub(crate) segments: Vec<Segment>,
}

/// Column-concatenate a group of same-key requests into one merged job
/// (row-major interleave of B and C), marking it routed when the total
/// column count is within the policy's routing threshold. Returns `None`
/// for an empty group.
pub(crate) fn merge_group(group: Vec<PendingReq>, policy: &BatchPolicy) -> Option<MergedJob> {
    if group.is_empty() {
        return None;
    }
    let image = group[0].req.image.clone();
    let (alpha, beta) = (group[0].req.alpha, group[0].req.beta);
    let m = image.image.m;
    let k = image.image.k;
    let n_total: usize = group.iter().map(|p| p.req.n).sum();
    let mut b_cat = vec![0f32; k * n_total];
    let mut c_cat = vec![0f32; m * n_total];
    let mut col = 0usize;
    let mut segments = Vec::with_capacity(group.len());
    for p in group {
        let req = p.req;
        for row in 0..k {
            b_cat[row * n_total + col..row * n_total + col + req.n]
                .copy_from_slice(&req.b[row * req.n..(row + 1) * req.n]);
        }
        for row in 0..m {
            c_cat[row * n_total + col..row * n_total + col + req.n]
                .copy_from_slice(&req.c[row * req.n..(row + 1) * req.n]);
        }
        segments.push(Segment {
            n: req.n,
            col_off: col,
            submitted: p.submitted,
            admitted: p.admitted,
            respond: p.respond,
            trace: p.trace,
            deadline: req.deadline,
        });
        col += req.n;
    }
    Some(MergedJob {
        image,
        alpha,
        beta,
        b_cat,
        c_cat,
        n_total,
        routed: policy.route_columns > 0 && n_total <= policy.route_columns,
        segments,
    })
}

/// Send the typed response for a request whose deadline expired while it
/// sat in the batcher, and release its admission slot. The timing carries
/// the real queue/batch waits so the shed still shows up in stage
/// histograms under the `"deadline"` backend label.
fn shed_expired(p: PendingReq, gate: &AdmissionGate, recorder: &Arc<Mutex<Recorder>>) {
    let now = Instant::now();
    let mut rec = recorder.lock().unwrap();
    rec.record_deadline(DeadlineStage::Batch);
    drop(rec);
    gate.release(p.req.image.id);
    let _ = p.respond.send(SpmmResponse {
        c: Vec::new(),
        timing: RequestTiming {
            queue: p.admitted.duration_since(p.submitted),
            batch: now.duration_since(p.admitted),
            prepare: Duration::ZERO,
            exec: Duration::ZERO,
            flops: 0,
            backend: "deadline",
            image: p.req.image.id,
        },
        error: Some("deadline exceeded while waiting in the batch queue".to_string()),
        rejected: Some(RejectKind::DeadlineExceeded),
    });
}

/// The batching loop: group pending requests by (image id, α bits, β bits),
/// flush a group when it reaches [`BatchPolicy::max_columns`] or the merge
/// window expires, and hand merged jobs to the dispatch stage. At every
/// flush, requests whose absolute deadline already passed are peeled off
/// first — they get a typed [`RejectKind::DeadlineExceeded`] response and
/// their admission slot back instead of burning a worker on a result
/// nobody is waiting for.
pub(crate) fn batcher_loop(
    rx: Receiver<Msg>,
    job_tx: Sender<MergedJob>,
    policy: BatchPolicy,
    recorder: Arc<Mutex<Recorder>>,
    gate: Arc<AdmissionGate>,
    sink: Option<Arc<dyn TelemetrySink>>,
) {
    type Key = (u64, u32, u32);
    let mut pending: HashMap<Key, Vec<PendingReq>> = HashMap::new();
    let mut deadline: Option<Instant> = None;

    let flush = |group: Vec<PendingReq>,
                 job_tx: &Sender<MergedJob>,
                 recorder: &Arc<Mutex<Recorder>>| {
        let now = Instant::now();
        let (live, expired): (Vec<PendingReq>, Vec<PendingReq>) = group
            .into_iter()
            .partition(|p| p.req.deadline.map_or(true, |d| now < d));
        for p in expired {
            shed_expired(p, &gate, recorder);
        }
        let len = live.len();
        if let Some(job) = merge_group(live, &policy) {
            recorder.lock().unwrap().record_batch(len);
            let _ = job_tx.send(job);
        }
    };

    loop {
        let timeout = deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Request(req, respond, submitted, trace)) => {
                let admitted = Instant::now();
                // The queue span covers submit -> batcher pickup, stamped
                // from the same Instants RequestTiming::queue is computed
                // from in dispatch.
                if let (Some(sink), Some(ctx)) = (sink.as_ref(), trace) {
                    sink.emit(SpanRecord::from_instants(
                        ctx.trace_id,
                        Some(ctx.root_id),
                        "queue",
                        submitted,
                        admitted,
                    ));
                }
                let key = (req.image.id, req.alpha.to_bits(), req.beta.to_bits());
                let group = pending.entry(key).or_default();
                group.push(PendingReq { req, respond, submitted, admitted, trace });
                let cols: usize = group.iter().map(|p| p.req.n).sum();
                if cols >= policy.max_columns {
                    let group = pending.remove(&key).unwrap();
                    flush(group, &job_tx, &recorder);
                }
                if deadline.is_none() && !pending.is_empty() {
                    deadline = Some(Instant::now() + policy.window);
                }
            }
            Ok(Msg::Shutdown) => {
                for (_, group) in pending.drain() {
                    flush(group, &job_tx, &recorder);
                }
                break; // dropping job_tx stops workers
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                for (_, group) in pending.drain() {
                    flush(group, &job_tx, &recorder);
                }
                deadline = None;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                for (_, group) in pending.drain() {
                    flush(group, &job_tx, &recorder);
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};
    use std::sync::mpsc;

    fn handle(seed: u64) -> ImageHandle {
        let mut rng = Rng::new(seed);
        let coo = gen::random_uniform(6, 4, 0.5, &mut rng);
        ImageHandle { id: seed, image: Arc::new(preprocess(&coo, 2, 4, 2)) }
    }

    fn pending(image: &ImageHandle, n: usize, fill_b: f32, fill_c: f32) -> PendingReq {
        // The receiver is dropped — merge_group only stores the sender.
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        PendingReq {
            req: SpmmRequest {
                image: image.clone(),
                b: vec![fill_b; image.image.k * n],
                c: vec![fill_c; image.image.m * n],
                n,
                alpha: 1.0,
                beta: 0.0,
                deadline: None,
            },
            respond: tx,
            submitted: now,
            admitted: now,
            trace: None,
        }
    }

    #[test]
    fn empty_group_merges_to_none() {
        let policy = BatchPolicy::default();
        assert!(merge_group(Vec::new(), &policy).is_none());
    }

    #[test]
    fn merge_concatenates_columns_in_order() {
        let policy = BatchPolicy { route_columns: 0, ..BatchPolicy::default() };
        let img = handle(1);
        let (k, m) = (img.image.k, img.image.m);
        let group = vec![pending(&img, 2, 1.0, 10.0), pending(&img, 3, 2.0, 20.0)];
        let job = merge_group(group, &policy).unwrap();
        assert_eq!(job.n_total, 5);
        assert!(!job.routed, "route_columns = 0 disables routing");
        assert_eq!(job.segments.len(), 2);
        assert_eq!((job.segments[0].n, job.segments[0].col_off), (2, 0));
        assert_eq!((job.segments[1].n, job.segments[1].col_off), (3, 2));
        // Row-major interleave: each B row holds 2 cols of request 0 then
        // 3 cols of request 1.
        for row in 0..k {
            assert_eq!(&job.b_cat[row * 5..row * 5 + 2], &[1.0, 1.0]);
            assert_eq!(&job.b_cat[row * 5 + 2..row * 5 + 5], &[2.0, 2.0, 2.0]);
        }
        for row in 0..m {
            assert_eq!(&job.c_cat[row * 5..row * 5 + 2], &[10.0, 10.0]);
            assert_eq!(&job.c_cat[row * 5 + 2..row * 5 + 5], &[20.0, 20.0, 20.0]);
        }
    }

    #[test]
    fn expired_requests_are_shed_at_flush_with_slot_released() {
        use super::super::admission::{Admit, AdmissionGate, AdmissionPolicy};
        use super::super::server::RejectKind;

        let img = handle(3);
        let gate = Arc::new(AdmissionGate::new(AdmissionPolicy::default()));
        assert!(matches!(gate.try_admit(img.id), Admit::Admitted));
        let recorder = Arc::new(Mutex::new(Recorder::default()));
        let (msg_tx, msg_rx) = mpsc::channel();
        let (job_tx, job_rx) = mpsc::channel();
        let batcher = {
            let recorder = Arc::clone(&recorder);
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || {
                batcher_loop(msg_rx, job_tx, BatchPolicy::default(), recorder, gate, None)
            })
        };
        let mut p = pending(&img, 2, 1.0, 0.0);
        p.req.deadline = Some(Instant::now() - Duration::from_millis(1));
        let (resp_tx, resp_rx) = mpsc::channel();
        msg_tx
            .send(Msg::Request(p.req, resp_tx, p.submitted, None))
            .unwrap();
        let resp = resp_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.rejected, Some(RejectKind::DeadlineExceeded));
        assert!(resp.error.as_deref().unwrap_or("").contains("deadline"));
        assert!(resp.c.is_empty(), "a shed request must not pay an M x n allocation");
        msg_tx.send(Msg::Shutdown).unwrap();
        batcher.join().unwrap();
        assert!(job_rx.try_recv().is_err(), "the expired request must not become a job");
        assert_eq!(gate.in_flight(), 0, "the admission slot is released at shed");
        assert_eq!(recorder.lock().unwrap().summary().deadline_batch, 1);
    }

    #[test]
    fn small_jobs_are_marked_routed() {
        let policy = BatchPolicy { route_columns: 4, ..BatchPolicy::default() };
        let img = handle(2);
        let job = merge_group(vec![pending(&img, 3, 0.0, 0.0)], &policy).unwrap();
        assert!(job.routed, "3 <= 4 columns must route");
        let group = vec![pending(&img, 3, 0.0, 0.0), pending(&img, 3, 0.0, 0.0)];
        let job = merge_group(group, &policy).unwrap();
        assert!(!job.routed, "6 > 4 columns must not route");
    }
}
