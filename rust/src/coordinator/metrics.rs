//! Serving metrics: per-request latency recording and summary statistics.

use std::time::Duration;

/// One served request's timing.
#[derive(Clone, Copy, Debug)]
pub struct RequestTiming {
    /// Time from submit to dispatch (queue + batching delay).
    pub queue: Duration,
    /// Executor time.
    pub exec: Duration,
    /// Problem size in FLOP.
    pub flops: u64,
    /// Name of the backend that executed the request (mixed-backend
    /// deployments stay attributable).
    pub backend: &'static str,
}

impl RequestTiming {
    /// End-to-end latency.
    pub fn total(&self) -> Duration {
        self.queue + self.exec
    }
}

/// Accumulates request timings; thread-safe via external Mutex.
#[derive(Debug, Default)]
pub struct Recorder {
    timings: Vec<RequestTiming>,
    batches: usize,
    batched_requests: usize,
}

impl Recorder {
    /// Record one request.
    pub fn record(&mut self, t: RequestTiming) {
        self.timings.push(t);
    }

    /// Record a dispatched batch of `n` requests.
    pub fn record_batch(&mut self, n: usize) {
        self.batches += 1;
        self.batched_requests += n;
    }

    /// Summarize.
    pub fn summary(&self) -> Summary {
        let mut totals: Vec<f64> =
            self.timings.iter().map(|t| t.total().as_secs_f64()).collect();
        totals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if totals.is_empty() {
                return 0.0;
            }
            let idx = ((totals.len() as f64 - 1.0) * p).round() as usize;
            totals[idx]
        };
        let total_flops: u64 = self.timings.iter().map(|t| t.flops).sum();
        let wall: f64 = totals.iter().sum();
        let mut backends: Vec<(&'static str, usize)> = Vec::new();
        for t in &self.timings {
            match backends.iter_mut().find(|(name, _)| *name == t.backend) {
                Some((_, count)) => *count += 1,
                None => backends.push((t.backend, 1)),
            }
        }
        backends.sort_by_key(|(name, _)| *name);
        Summary {
            requests: self.timings.len(),
            batches: self.batches,
            mean_batch: if self.batches == 0 {
                0.0
            } else {
                self.batched_requests as f64 / self.batches as f64
            },
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
            total_flops,
            sum_latency_s: wall,
            backends,
        }
    }
}

/// Aggregated serving statistics.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Requests served.
    pub requests: usize,
    /// Batches dispatched.
    pub batches: usize,
    /// Mean requests per batch.
    pub mean_batch: f64,
    /// Median end-to-end latency (s).
    pub p50_s: f64,
    /// 95th percentile latency (s).
    pub p95_s: f64,
    /// 99th percentile latency (s).
    pub p99_s: f64,
    /// Total FLOPs served.
    pub total_flops: u64,
    /// Sum of request latencies (s).
    pub sum_latency_s: f64,
    /// Requests served per backend name, sorted by name.
    pub backends: Vec<(&'static str, usize)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64, flops: u64) -> RequestTiming {
        tb(ms, flops, "test")
    }

    fn tb(ms: u64, flops: u64, backend: &'static str) -> RequestTiming {
        RequestTiming {
            queue: Duration::from_millis(ms / 2),
            exec: Duration::from_millis(ms - ms / 2),
            flops,
            backend,
        }
    }

    #[test]
    fn summary_percentiles() {
        let mut r = Recorder::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            r.record(t(ms, 10));
        }
        let s = r.summary();
        assert_eq!(s.requests, 10);
        assert!((s.p50_s - 0.005).abs() < 0.0015, "{}", s.p50_s);
        assert!(s.p99_s >= 0.09);
        assert_eq!(s.total_flops, 100);
    }

    #[test]
    fn batch_accounting() {
        let mut r = Recorder::default();
        r.record_batch(3);
        r.record_batch(1);
        let s = r.summary();
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_recorder_summary_is_zero() {
        let s = Recorder::default().summary();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50_s, 0.0);
        assert!(s.backends.is_empty());
    }

    #[test]
    fn backend_attribution_counts_per_name() {
        let mut r = Recorder::default();
        r.record(tb(1, 10, "native"));
        r.record(tb(2, 10, "functional"));
        r.record(tb(3, 10, "native"));
        let s = r.summary();
        assert_eq!(s.backends, vec![("functional", 1), ("native", 2)]);
    }
}
