//! Serving metrics: per-request latency with a **per-stage breakdown**
//! (queue wait → batch wait → prepare → execute, matching the pipeline's
//! four stages), prepare amortization (shared prepared-handle cache hits
//! vs. misses, byte-budget evictions), admission rejections (total and
//! per-image-quota), shard-aware routing counts, re-shard-on-skew
//! rebuilds, execution concurrency (the [`ConcurrencyGauge`] high-water
//! mark proving shared handles really execute in parallel), and
//! shard-level load statistics — rolled up into [`Summary`].
//!
//! Latency aggregation is **streaming**: the recorder holds fixed-memory
//! log-bucketed [`Histogram`]s (one for end-to-end latency, one per
//! pipeline stage, one per backend) instead of the unbounded timing `Vec`
//! it used to keep, so p50/p95/p99 stay available — per stage and per
//! backend — no matter how long the server runs (± 2.2% relative bucket
//! error; counts, sums, and stage means remain exact). Per-image
//! aggregates attribute load to the matrix that caused it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use crate::backend::{PrepareCost, RemoteStats};
use crate::shard::ShardRunStats;
use crate::telemetry::histogram::{Histogram, Percentiles};
use crate::telemetry::json::{self, Value};

/// Counts overlapping executions across the worker pool: `enter` bumps the
/// live count (returning an RAII guard that drops it) and folds it into a
/// monotonic high-water mark. Lock-free — two atomics — so the gauge adds
/// nothing measurable to the execute path it instruments.
#[derive(Debug, Default)]
pub struct ConcurrencyGauge {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl ConcurrencyGauge {
    /// A gauge at zero.
    pub fn new() -> ConcurrencyGauge {
        ConcurrencyGauge::default()
    }

    /// Mark one execution as live until the returned guard drops.
    pub fn enter(&self) -> ConcurrencyGuard<'_> {
        let now = self.current.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(now, Ordering::Relaxed);
        ConcurrencyGuard(self)
    }

    /// Executions live right now.
    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    /// High-water mark of simultaneously live executions.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

/// RAII token from [`ConcurrencyGauge::enter`]; dropping it ends the
/// execution it marked live.
pub struct ConcurrencyGuard<'g>(&'g ConcurrencyGauge);

impl Drop for ConcurrencyGuard<'_> {
    fn drop(&mut self) {
        self.0.current.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Which pipeline stage observed an expired request deadline. Every
/// deadline shed is counted at exactly one stage, so the three counters
/// in [`Summary`] partition the total.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeadlineStage {
    /// Expired at submit, before an admission slot was consumed.
    Admission,
    /// Expired while waiting in the batcher (slot released at flush).
    Batch,
    /// Expired at dispatch pickup, just before execute (slot released).
    Dispatch,
}

/// One served request's timing, decomposed by pipeline stage.
#[derive(Clone, Copy, Debug)]
pub struct RequestTiming {
    /// Stage 1→2 wait: submit until the batcher admits it to a merge
    /// group.
    pub queue: Duration,
    /// Stage 2→3 wait: merge-group admission until a worker picks the
    /// merged job up (window wait + dispatch queue).
    pub batch: Duration,
    /// Stage 4: residency resolution — a cache hit is ~0, a miss pays the
    /// backend's prepare (waiting out another worker's in-flight prepare
    /// of the same image counts here too).
    pub prepare: Duration,
    /// Pure executor time. Shared handles execute through `&self` with no
    /// per-matrix lock, so nothing but the engine is ever folded in here;
    /// any worker-side stall upstream of execution shows up as queue or
    /// batch wait instead.
    pub exec: Duration,
    /// Problem size in FLOP.
    pub flops: u64,
    /// Name of the backend that executed the request (mixed-backend
    /// deployments stay attributable).
    pub backend: &'static str,
    /// Id of the matrix image the request ran against (0 for requests
    /// that never reached an image, e.g. admission rejects).
    pub image: u64,
}

impl RequestTiming {
    /// End-to-end latency: the sum of all four stages.
    pub fn total(&self) -> Duration {
        self.queue + self.batch + self.prepare + self.exec
    }
}

/// Per-image serving aggregates: which matrix generated the load.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ImageSummary {
    /// Image id (as carried by `ImageHandle`).
    pub image: u64,
    /// Requests served against this image.
    pub requests: usize,
    /// Sum of those requests' end-to-end latencies (s).
    pub sum_latency_s: f64,
    /// FLOPs served against this image.
    pub flops: u64,
}

/// Accumulates request timings; thread-safe via external Mutex. Fixed
/// memory: histograms per stage/backend plus small per-image aggregates.
#[derive(Debug, Default)]
pub struct Recorder {
    total_hist: Histogram,
    queue_hist: Histogram,
    batch_hist: Histogram,
    prepare_hist: Histogram,
    exec_hist: Histogram,
    /// Exact running stage sums — the stage means must decompose the mean
    /// latency exactly, which bucketed estimates cannot guarantee.
    queue_sum_s: f64,
    batch_sum_s: f64,
    prepare_sum_s: f64,
    exec_sum_s: f64,
    total_flops: u64,
    /// Per-backend request count and end-to-end latency histogram
    /// (insertion order; sorted at summary time).
    per_backend: Vec<(&'static str, usize, Histogram)>,
    /// Per-image aggregates (insertion order; sorted at summary time).
    per_image: Vec<ImageSummary>,
    batches: usize,
    batched_requests: usize,
    rejected: usize,
    deadline_admission: usize,
    deadline_batch: usize,
    deadline_dispatch: usize,
    /// Per-image-quota sheds, keyed by image id (insertion order).
    image_sheds: Vec<(u64, usize)>,
    exec_concurrency_peak: usize,
    prepares: usize,
    prepare_hits: usize,
    prepare_wall_s: f64,
    prepared_bytes: u64,
    evictions: usize,
    routed_jobs: usize,
    shards_skipped: usize,
    reshards: usize,
    last_reshard: Option<(usize, usize)>,
    shard_execs: usize,
    shard_count_sum: usize,
    shard_imbalance_sum: f64,
    shard_imbalance_max: f64,
    shard_slowest_s_sum: f64,
    remote_execs: usize,
    remote_retries: usize,
    remote_replaced: usize,
    /// Latest fleet view (last-wins gauges from the most recent remote
    /// execution's [`RemoteStats`]).
    remote_fleet: Option<RemoteStats>,
}

impl Recorder {
    /// Record one request.
    pub fn record(&mut self, t: RequestTiming) {
        let total = t.total().as_secs_f64();
        self.total_hist.record(total);
        self.queue_hist.record(t.queue.as_secs_f64());
        self.batch_hist.record(t.batch.as_secs_f64());
        self.prepare_hist.record(t.prepare.as_secs_f64());
        self.exec_hist.record(t.exec.as_secs_f64());
        self.queue_sum_s += t.queue.as_secs_f64();
        self.batch_sum_s += t.batch.as_secs_f64();
        self.prepare_sum_s += t.prepare.as_secs_f64();
        self.exec_sum_s += t.exec.as_secs_f64();
        self.total_flops += t.flops;
        match self.per_backend.iter_mut().find(|(name, _, _)| *name == t.backend) {
            Some((_, count, hist)) => {
                *count += 1;
                hist.record(total);
            }
            None => {
                let mut hist = Histogram::new();
                hist.record(total);
                self.per_backend.push((t.backend, 1, hist));
            }
        }
        match self.per_image.iter_mut().find(|s| s.image == t.image) {
            Some(s) => {
                s.requests += 1;
                s.sum_latency_s += total;
                s.flops += t.flops;
            }
            None => self.per_image.push(ImageSummary {
                image: t.image,
                requests: 1,
                sum_latency_s: total,
                flops: t.flops,
            }),
        }
    }

    /// Record a dispatched batch of `n` requests.
    pub fn record_batch(&mut self, n: usize) {
        self.batches += 1;
        self.batched_requests += n;
    }

    /// Record one request shed by the admission gate (never queued).
    pub fn record_reject(&mut self) {
        self.rejected += 1;
    }

    /// Record one request shed because its absolute deadline expired,
    /// attributed to the pipeline stage that noticed it. Deadline sheds
    /// are *not* folded into [`Summary::rejected`] — an expired request
    /// is the caller's budget running out, not the server shedding load.
    pub fn record_deadline(&mut self, stage: DeadlineStage) {
        match stage {
            DeadlineStage::Admission => self.deadline_admission += 1,
            DeadlineStage::Batch => self.deadline_batch += 1,
            DeadlineStage::Dispatch => self.deadline_dispatch += 1,
        }
    }

    /// Record one request shed by the *per-image* quota (also counted in
    /// [`Recorder::record_reject`] by the caller), attributed to its image
    /// so the summary can show which matrix was hogging the gate.
    pub fn record_image_shed(&mut self, image_id: u64) {
        match self.image_sheds.iter_mut().find(|(id, _)| *id == image_id) {
            Some((_, count)) => *count += 1,
            None => self.image_sheds.push((image_id, 1)),
        }
    }

    /// Fold an observed execution-concurrency high-water mark into the
    /// summary (monotonic max; fed from the dispatch stage's
    /// [`ConcurrencyGauge`]).
    pub fn record_exec_concurrency(&mut self, peak: usize) {
        self.exec_concurrency_peak = self.exec_concurrency_peak.max(peak);
    }

    /// Record one matrix becoming resident (a prepared-handle cache miss).
    pub fn record_prepare(&mut self, cost: &PrepareCost) {
        self.prepares += 1;
        self.prepare_wall_s += cost.wall.as_secs_f64();
        self.prepared_bytes += cost.resident_bytes;
    }

    /// Record one job served from an already-resident prepared handle.
    pub fn record_prepare_hit(&mut self) {
        self.prepare_hits += 1;
    }

    /// Record one residency evicted by the byte-budget policy.
    pub fn record_evict(&mut self) {
        self.evictions += 1;
    }

    /// Record one merged job dispatched through the shard-aware routed
    /// path, with the number of shards it skipped.
    pub fn record_routed(&mut self, skipped: usize) {
        self.routed_jobs += 1;
        self.shards_skipped += skipped;
    }

    /// Record one skew-triggered rebuild: the resident sharded handle was
    /// dropped and re-prepared at a new shard count. Deliberately not
    /// folded into the prepare aggregates — a rebuild is neither a cache
    /// miss nor a caller-visible prepare, and mixing its wall time into
    /// `mean_prepare_s` would skew that mean's denominator.
    pub fn record_reshard(&mut self, from_shards: usize, to_shards: usize) {
        self.reshards += 1;
        self.last_reshard = Some((from_shards, to_shards));
    }

    /// Record one sharded execution's shard-level stats (per-shard nnz and
    /// latency roll up into imbalance and makespan aggregates).
    pub fn record_shards(&mut self, stats: &ShardRunStats) {
        self.shard_execs += 1;
        self.shard_count_sum += stats.shards;
        self.shard_imbalance_sum += stats.imbalance;
        if stats.imbalance > self.shard_imbalance_max {
            self.shard_imbalance_max = stats.imbalance;
        }
        self.shard_slowest_s_sum += stats.slowest().as_secs_f64();
    }

    /// Record one distributed execution's fleet stats: retry/re-place
    /// event counters accumulate, the fleet shape (workers, live workers,
    /// placements, replicas) is a last-wins gauge — it describes the
    /// fleet *now*, not a sum over history.
    pub fn record_remote(&mut self, stats: &RemoteStats) {
        self.remote_execs += 1;
        self.remote_retries += stats.retries;
        self.remote_replaced += stats.replaced;
        self.remote_fleet = Some(*stats);
    }

    /// Summarize.
    pub fn summary(&self) -> Summary {
        let requests = self.total_hist.count() as usize;
        let denom = requests.max(1) as f64;
        let total_pct = self.total_hist.percentiles();
        let mut backends: Vec<(&'static str, usize)> =
            self.per_backend.iter().map(|(name, count, _)| (*name, *count)).collect();
        backends.sort_by_key(|(name, _)| *name);
        let mut backend_latency: Vec<(&'static str, Percentiles)> =
            self.per_backend.iter().map(|(name, _, h)| (*name, h.percentiles())).collect();
        backend_latency.sort_by_key(|(name, _)| *name);
        let mut images = self.per_image.clone();
        images.sort_by_key(|s| s.image);
        Summary {
            requests,
            batches: self.batches,
            mean_batch: if self.batches == 0 {
                0.0
            } else {
                self.batched_requests as f64 / self.batches as f64
            },
            rejected: self.rejected,
            deadline_admission: self.deadline_admission,
            deadline_batch: self.deadline_batch,
            deadline_dispatch: self.deadline_dispatch,
            image_sheds: {
                let mut sheds = self.image_sheds.clone();
                sheds.sort_by_key(|&(id, _)| id);
                sheds
            },
            exec_concurrency_peak: self.exec_concurrency_peak,
            p50_s: total_pct.p50,
            p95_s: total_pct.p95,
            p99_s: total_pct.p99,
            total_flops: self.total_flops,
            sum_latency_s: self.total_hist.sum(),
            stage_queue_s: self.queue_sum_s / denom,
            stage_batch_s: self.batch_sum_s / denom,
            stage_prepare_s: self.prepare_sum_s / denom,
            stage_exec_s: self.exec_sum_s / denom,
            stage_queue_pct: self.queue_hist.percentiles(),
            stage_batch_pct: self.batch_hist.percentiles(),
            stage_prepare_pct: self.prepare_hist.percentiles(),
            stage_exec_pct: self.exec_hist.percentiles(),
            backends,
            backend_latency,
            images,
            prepares: self.prepares,
            prepare_hits: self.prepare_hits,
            prepare_hit_rate: if self.prepares + self.prepare_hits == 0 {
                0.0
            } else {
                self.prepare_hits as f64 / (self.prepares + self.prepare_hits) as f64
            },
            mean_prepare_s: if self.prepares == 0 {
                0.0
            } else {
                self.prepare_wall_s / self.prepares as f64
            },
            prepared_bytes: self.prepared_bytes,
            evictions: self.evictions,
            routed_jobs: self.routed_jobs,
            shards_skipped: self.shards_skipped,
            reshards: self.reshards,
            last_reshard: self.last_reshard,
            shard_execs: self.shard_execs,
            mean_shards: if self.shard_execs == 0 {
                0.0
            } else {
                self.shard_count_sum as f64 / self.shard_execs as f64
            },
            mean_shard_imbalance: if self.shard_execs == 0 {
                0.0
            } else {
                self.shard_imbalance_sum / self.shard_execs as f64
            },
            max_shard_imbalance: self.shard_imbalance_max,
            mean_shard_makespan_s: if self.shard_execs == 0 {
                0.0
            } else {
                self.shard_slowest_s_sum / self.shard_execs as f64
            },
            remote_execs: self.remote_execs,
            remote_retries: self.remote_retries,
            remote_replaced: self.remote_replaced,
            remote_workers: self.remote_fleet.map_or(0, |f| f.workers),
            remote_live_workers: self.remote_fleet.map_or(0, |f| f.live_workers),
            remote_placements: self.remote_fleet.map_or(0, |f| f.placements),
            remote_replicas: self.remote_fleet.map_or(0, |f| f.replicas),
            remote_breaker_trips: self.remote_fleet.map_or(0, |f| f.breaker_trips),
            remote_transitions: self.remote_fleet.map_or(0, |f| f.transitions),
            remote_rebalanced: self.remote_fleet.map_or(0, |f| f.rebalanced),
        }
    }
}

/// Aggregated serving statistics.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Requests served.
    pub requests: usize,
    /// Batches dispatched.
    pub batches: usize,
    /// Mean requests per batch.
    pub mean_batch: f64,
    /// Requests shed by the admission gate (not counted in `requests`).
    pub rejected: usize,
    /// Requests whose deadline expired at submit, before consuming an
    /// admission slot (not counted in `requests` or `rejected`).
    pub deadline_admission: usize,
    /// Requests whose deadline expired waiting in the batcher; their
    /// admission slot was released at flush.
    pub deadline_batch: usize,
    /// Requests whose deadline expired at dispatch pickup, just before
    /// execute; their admission slot was released.
    pub deadline_dispatch: usize,
    /// Of `rejected`, sheds caused by the per-image in-flight quota,
    /// attributed to the image that was over quota — (image id, count),
    /// sorted by id. Empty when the quota is off or never tripped.
    pub image_sheds: Vec<(u64, usize)>,
    /// High-water mark of simultaneously executing requests across the
    /// worker pool. With shared `&self` handles this reaches the worker
    /// count on a single-hot-matrix workload — the observable proof that
    /// the per-matrix lock is gone (1 means executions never overlapped).
    pub exec_concurrency_peak: usize,
    /// Median end-to-end latency (s).
    pub p50_s: f64,
    /// 95th percentile latency (s).
    pub p95_s: f64,
    /// 99th percentile latency (s).
    pub p99_s: f64,
    /// Total FLOPs served.
    pub total_flops: u64,
    /// Sum of request latencies (s).
    pub sum_latency_s: f64,
    /// Mean per-request queue wait: submit → batcher admission (s).
    pub stage_queue_s: f64,
    /// Mean per-request batch wait: admission → worker pickup (s).
    pub stage_batch_s: f64,
    /// Mean per-request residency resolution: cache hit or prepare (s).
    pub stage_prepare_s: f64,
    /// Mean per-request execution time (s).
    pub stage_exec_s: f64,
    /// Queue-wait p50/p95/p99 (s), from the streaming stage histogram.
    pub stage_queue_pct: Percentiles,
    /// Batch-wait p50/p95/p99 (s).
    pub stage_batch_pct: Percentiles,
    /// Residency-resolution p50/p95/p99 (s) — tail shows cold prepares.
    pub stage_prepare_pct: Percentiles,
    /// Execution p50/p95/p99 (s).
    pub stage_exec_pct: Percentiles,
    /// Requests served per backend name, sorted by name.
    pub backends: Vec<(&'static str, usize)>,
    /// End-to-end latency percentiles per backend name, sorted by name
    /// (same names as [`Summary::backends`]).
    pub backend_latency: Vec<(&'static str, Percentiles)>,
    /// Per-image request/latency/FLOP aggregates, sorted by image id.
    pub images: Vec<ImageSummary>,
    /// Matrix prepares performed (prepared-handle cache misses; each pays
    /// the backend's build path once, shared across workers).
    pub prepares: usize,
    /// Jobs served from the prepared-handle cache (no re-prepare).
    pub prepare_hits: usize,
    /// hits / (hits + prepares) — the amortization headline: how often a
    /// request found its matrix already resident.
    pub prepare_hit_rate: f64,
    /// Mean wall time per prepare (s); skew rebuilds are counted in
    /// [`Summary::reshards`], not here.
    pub mean_prepare_s: f64,
    /// Total bytes made resident by prepares (decoded streams, shard
    /// images, scratch).
    pub prepared_bytes: u64,
    /// Residencies evicted by the byte-budget policy.
    pub evictions: usize,
    /// Merged jobs dispatched through the shard-aware routed path on a
    /// sharded handle (plain-engine jobs under the routing threshold are
    /// not counted — there is nothing to skip).
    pub routed_jobs: usize,
    /// Total shards skipped across routed executions (shards owning no
    /// non-zeros of the touched rows).
    pub shards_skipped: usize,
    /// Skew-triggered rebuilds (drop + re-prepare at a new shard count).
    pub reshards: usize,
    /// Most recent rebuild as (from, to) shard counts.
    pub last_reshard: Option<(usize, usize)>,
    /// Sharded executions observed (0 when no sharded backend served).
    pub shard_execs: usize,
    /// Mean shard count per sharded execution.
    pub mean_shards: f64,
    /// Mean max/mean shard-nnz imbalance across sharded executions.
    pub mean_shard_imbalance: f64,
    /// Worst shard-nnz imbalance observed.
    pub max_shard_imbalance: f64,
    /// Mean slowest-shard (makespan) latency per sharded execution (s).
    pub mean_shard_makespan_s: f64,
    /// Distributed executions observed (0 when no `remote:` backend
    /// served).
    pub remote_execs: usize,
    /// Failed remote RPC attempts retried on another replica, summed
    /// across distributed executions.
    pub remote_retries: usize,
    /// Shards re-placed onto a fresh worker mid-stream, summed across
    /// distributed executions.
    pub remote_replaced: usize,
    /// Fleet size of the most recent distributed execution (gauge).
    pub remote_workers: usize,
    /// Workers still live after the most recent distributed execution
    /// (gauge).
    pub remote_live_workers: usize,
    /// Shard placements (replicas included) live across the fleet after
    /// the most recent distributed execution (gauge).
    pub remote_placements: usize,
    /// Configured replication factor of the serving fleet (gauge).
    pub remote_replicas: usize,
    /// Circuit-breaker trips (closed → open edges) observed by the fleet
    /// supervisor, as of the most recent distributed execution (gauge —
    /// the fleet counter is cumulative since prepare).
    pub remote_breaker_trips: usize,
    /// Worker liveness transitions (Live/Suspect/Dead edges, any
    /// direction) observed by the heartbeat supervisor, as of the most
    /// recent distributed execution (gauge).
    pub remote_transitions: usize,
    /// Shard placements proactively moved by membership-driven
    /// rebalancing, as of the most recent distributed execution (gauge).
    pub remote_rebalanced: usize,
}

fn percentiles_value(p: &Percentiles) -> Value {
    json::obj(vec![
        ("p50_s", json::num(p.p50)),
        ("p95_s", json::num(p.p95)),
        ("p99_s", json::num(p.p99)),
    ])
}

impl Summary {
    /// Serialize the summary as JSON (the `serve --metrics-json` payload).
    /// Stage entries carry both the exact mean and the histogram
    /// percentiles; backends merge count and latency percentiles per name.
    pub fn to_value(&self) -> Value {
        let stage = |mean: f64, pct: &Percentiles| -> Value {
            json::obj(vec![
                ("mean_s", json::num(mean)),
                ("p50_s", json::num(pct.p50)),
                ("p95_s", json::num(pct.p95)),
                ("p99_s", json::num(pct.p99)),
            ])
        };
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("batches", json::num(self.batches as f64)),
            ("mean_batch", json::num(self.mean_batch)),
            ("rejected", json::num(self.rejected as f64)),
            (
                "deadline",
                json::obj(vec![
                    ("admission", json::num(self.deadline_admission as f64)),
                    ("batch", json::num(self.deadline_batch as f64)),
                    ("dispatch", json::num(self.deadline_dispatch as f64)),
                ]),
            ),
            (
                "image_sheds",
                Value::Arr(
                    self.image_sheds
                        .iter()
                        .map(|(id, count)| {
                            json::obj(vec![
                                ("image", json::num(*id as f64)),
                                ("sheds", json::num(*count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("exec_concurrency_peak", json::num(self.exec_concurrency_peak as f64)),
            ("latency", percentiles_value(&Percentiles {
                p50: self.p50_s,
                p95: self.p95_s,
                p99: self.p99_s,
            })),
            ("total_flops", json::num(self.total_flops as f64)),
            ("sum_latency_s", json::num(self.sum_latency_s)),
            (
                "stages",
                json::obj(vec![
                    ("queue", stage(self.stage_queue_s, &self.stage_queue_pct)),
                    ("batch", stage(self.stage_batch_s, &self.stage_batch_pct)),
                    ("prepare", stage(self.stage_prepare_s, &self.stage_prepare_pct)),
                    ("exec", stage(self.stage_exec_s, &self.stage_exec_pct)),
                ]),
            ),
            (
                "backends",
                Value::Arr(
                    self.backends
                        .iter()
                        .map(|(name, count)| {
                            let pct = self
                                .backend_latency
                                .iter()
                                .find(|(n, _)| n == name)
                                .map(|(_, p)| *p)
                                .unwrap_or_default();
                            json::obj(vec![
                                ("backend", json::s(*name)),
                                ("requests", json::num(*count as f64)),
                                ("p50_s", json::num(pct.p50)),
                                ("p95_s", json::num(pct.p95)),
                                ("p99_s", json::num(pct.p99)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "images",
                Value::Arr(
                    self.images
                        .iter()
                        .map(|i| {
                            json::obj(vec![
                                ("image", json::num(i.image as f64)),
                                ("requests", json::num(i.requests as f64)),
                                ("sum_latency_s", json::num(i.sum_latency_s)),
                                ("flops", json::num(i.flops as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("prepares", json::num(self.prepares as f64)),
            ("prepare_hits", json::num(self.prepare_hits as f64)),
            ("prepare_hit_rate", json::num(self.prepare_hit_rate)),
            ("mean_prepare_s", json::num(self.mean_prepare_s)),
            ("prepared_bytes", json::num(self.prepared_bytes as f64)),
            ("evictions", json::num(self.evictions as f64)),
            ("routed_jobs", json::num(self.routed_jobs as f64)),
            ("shards_skipped", json::num(self.shards_skipped as f64)),
            ("reshards", json::num(self.reshards as f64)),
            ("shard_execs", json::num(self.shard_execs as f64)),
            ("mean_shards", json::num(self.mean_shards)),
            ("mean_shard_imbalance", json::num(self.mean_shard_imbalance)),
            ("max_shard_imbalance", json::num(self.max_shard_imbalance)),
            ("mean_shard_makespan_s", json::num(self.mean_shard_makespan_s)),
            (
                "remote",
                json::obj(vec![
                    ("execs", json::num(self.remote_execs as f64)),
                    ("retries", json::num(self.remote_retries as f64)),
                    ("replaced", json::num(self.remote_replaced as f64)),
                    ("workers", json::num(self.remote_workers as f64)),
                    ("live_workers", json::num(self.remote_live_workers as f64)),
                    ("placements", json::num(self.remote_placements as f64)),
                    ("replicas", json::num(self.remote_replicas as f64)),
                    ("breaker_trips", json::num(self.remote_breaker_trips as f64)),
                    ("transitions", json::num(self.remote_transitions as f64)),
                    ("rebalanced", json::num(self.remote_rebalanced as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64, flops: u64) -> RequestTiming {
        tb(ms, flops, "test")
    }

    fn tb(ms: u64, flops: u64, backend: &'static str) -> RequestTiming {
        RequestTiming {
            queue: Duration::from_millis(ms / 2),
            batch: Duration::ZERO,
            prepare: Duration::ZERO,
            exec: Duration::from_millis(ms - ms / 2),
            flops,
            backend,
            image: 1,
        }
    }

    #[test]
    fn summary_percentiles() {
        let mut r = Recorder::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            r.record(t(ms, 10));
        }
        let s = r.summary();
        assert_eq!(s.requests, 10);
        assert!((s.p50_s - 0.005).abs() < 0.0015, "{}", s.p50_s);
        assert!(s.p99_s >= 0.09);
        assert_eq!(s.total_flops, 100);
    }

    #[test]
    fn stage_breakdown_means_sum_to_mean_latency() {
        let mut r = Recorder::default();
        r.record(RequestTiming {
            queue: Duration::from_millis(1),
            batch: Duration::from_millis(2),
            prepare: Duration::from_millis(3),
            exec: Duration::from_millis(4),
            flops: 1,
            backend: "test",
            image: 1,
        });
        r.record(RequestTiming {
            queue: Duration::from_millis(3),
            batch: Duration::from_millis(4),
            prepare: Duration::from_millis(5),
            exec: Duration::from_millis(8),
            flops: 1,
            backend: "test",
            image: 1,
        });
        let s = r.summary();
        assert!((s.stage_queue_s - 0.002).abs() < 1e-9);
        assert!((s.stage_batch_s - 0.003).abs() < 1e-9);
        assert!((s.stage_prepare_s - 0.004).abs() < 1e-9);
        assert!((s.stage_exec_s - 0.006).abs() < 1e-9);
        let stage_sum = s.stage_queue_s + s.stage_batch_s + s.stage_prepare_s + s.stage_exec_s;
        let mean_latency = s.sum_latency_s / s.requests as f64;
        assert!(
            (stage_sum - mean_latency).abs() < 1e-12,
            "stages must decompose the latency: {stage_sum} vs {mean_latency}"
        );
    }

    #[test]
    fn per_stage_percentiles_come_from_streaming_histograms() {
        let mut r = Recorder::default();
        // 20 requests: queue fixed at 1 ms, exec spread 1..=20 ms.
        for ms in 1..=20u64 {
            r.record(RequestTiming {
                queue: Duration::from_millis(1),
                batch: Duration::ZERO,
                prepare: Duration::ZERO,
                exec: Duration::from_millis(ms),
                flops: 0,
                backend: "test",
                image: 1,
            });
        }
        let s = r.summary();
        assert!((s.stage_queue_pct.p50 - 0.001).abs() / 0.001 < 0.045, "{:?}", s.stage_queue_pct);
        assert!(
            (s.stage_queue_pct.p99 - 0.001).abs() / 0.001 < 0.045,
            "constant stage has a flat tail: {:?}",
            s.stage_queue_pct
        );
        // Exec p50 ~ 10-11 ms, p99 ~ 20 ms (rank rounding), within bucket error.
        assert!((s.stage_exec_pct.p50 - 0.010).abs() < 0.002, "{:?}", s.stage_exec_pct);
        assert!((s.stage_exec_pct.p99 - 0.020).abs() < 0.002, "{:?}", s.stage_exec_pct);
        // Zero-valued stages report zero percentiles.
        assert_eq!(s.stage_batch_pct.p99, 0.0);
    }

    #[test]
    fn batch_accounting() {
        let mut r = Recorder::default();
        r.record_batch(3);
        r.record_batch(1);
        let s = r.summary();
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_recorder_summary_is_zero() {
        let s = Recorder::default().summary();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p50_s, 0.0);
        assert!(s.backends.is_empty());
        assert!(s.backend_latency.is_empty());
        assert!(s.images.is_empty());
        assert_eq!(s.shard_execs, 0);
        assert_eq!(s.mean_shard_imbalance, 0.0);
        assert_eq!(s.prepares, 0);
        assert_eq!(s.prepare_hit_rate, 0.0);
        assert_eq!(s.stage_queue_s, 0.0);
        assert_eq!(s.stage_exec_s, 0.0);
        assert_eq!(s.stage_exec_pct, Percentiles::default());
        assert_eq!(s.rejected, 0);
        assert_eq!(s.deadline_admission, 0);
        assert_eq!(s.deadline_batch, 0);
        assert_eq!(s.deadline_dispatch, 0);
        assert!(s.image_sheds.is_empty());
        assert_eq!(s.exec_concurrency_peak, 0);
        assert_eq!(s.routed_jobs, 0);
        assert_eq!(s.reshards, 0);
        assert_eq!(s.last_reshard, None);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.remote_execs, 0);
        assert_eq!(s.remote_retries, 0);
        assert_eq!(s.remote_replaced, 0);
        assert_eq!(s.remote_workers, 0);
    }

    #[test]
    fn remote_accounting_sums_events_and_gauges_fleet_shape() {
        let mut r = Recorder::default();
        r.record_remote(&RemoteStats {
            workers: 3,
            live_workers: 3,
            placements: 6,
            replicas: 2,
            retries: 1,
            replaced: 0,
            breaker_trips: 0,
            transitions: 0,
            rebalanced: 0,
        });
        r.record_remote(&RemoteStats {
            workers: 3,
            live_workers: 2,
            placements: 5,
            replicas: 2,
            retries: 2,
            replaced: 1,
            breaker_trips: 1,
            transitions: 2,
            rebalanced: 3,
        });
        let s = r.summary();
        assert_eq!(s.remote_execs, 2);
        assert_eq!(s.remote_retries, 3, "retries accumulate across executions");
        assert_eq!(s.remote_replaced, 1);
        assert_eq!(s.remote_workers, 3);
        assert_eq!(s.remote_live_workers, 2, "fleet shape is last-wins");
        assert_eq!(s.remote_placements, 5);
        assert_eq!(s.remote_replicas, 2);
        assert_eq!(s.remote_breaker_trips, 1, "supervision gauges are last-wins");
        assert_eq!(s.remote_transitions, 2);
        assert_eq!(s.remote_rebalanced, 3);
        let v = s.to_value();
        let parsed = crate::telemetry::json::parse(&v.to_json_pretty()).unwrap();
        let remote = parsed.get("remote").unwrap();
        assert_eq!(remote.get("retries").and_then(Value::as_u64), Some(3));
        assert_eq!(remote.get("live_workers").and_then(Value::as_u64), Some(2));
        assert_eq!(remote.get("breaker_trips").and_then(Value::as_u64), Some(1));
        assert_eq!(remote.get("rebalanced").and_then(Value::as_u64), Some(3));
    }

    #[test]
    fn concurrency_gauge_tracks_overlap_and_peak() {
        let g = ConcurrencyGauge::new();
        assert_eq!((g.current(), g.peak()), (0, 0));
        {
            let _a = g.enter();
            let _b = g.enter();
            assert_eq!((g.current(), g.peak()), (2, 2));
        }
        assert_eq!(g.current(), 0, "guards release on drop");
        assert_eq!(g.peak(), 2, "the high-water mark is monotonic");
        let _c = g.enter();
        assert_eq!((g.current(), g.peak()), (1, 2));
    }

    #[test]
    fn image_sheds_and_exec_peak_aggregate() {
        let mut r = Recorder::default();
        r.record_image_shed(7);
        r.record_image_shed(3);
        r.record_image_shed(7);
        r.record_exec_concurrency(2);
        r.record_exec_concurrency(5);
        r.record_exec_concurrency(4);
        let s = r.summary();
        assert_eq!(s.image_sheds, vec![(3, 1), (7, 2)], "sorted by image id");
        assert_eq!(s.exec_concurrency_peak, 5, "peak is a monotonic max");
    }

    #[test]
    fn prepare_accounting_aggregates() {
        let mut r = Recorder::default();
        r.record_prepare(&PrepareCost {
            wall: Duration::from_millis(10),
            resident_bytes: 1_000,
        });
        r.record_prepare(&PrepareCost {
            wall: Duration::from_millis(30),
            resident_bytes: 3_000,
        });
        for _ in 0..6 {
            r.record_prepare_hit();
        }
        let s = r.summary();
        assert_eq!(s.prepares, 2);
        assert_eq!(s.prepare_hits, 6);
        assert!((s.prepare_hit_rate - 0.75).abs() < 1e-12);
        assert!((s.mean_prepare_s - 0.02).abs() < 1e-9);
        assert_eq!(s.prepared_bytes, 4_000);
    }

    #[test]
    fn pipeline_event_counters_aggregate() {
        let mut r = Recorder::default();
        r.record_reject();
        r.record_reject();
        r.record_evict();
        r.record_routed(5);
        r.record_routed(0);
        r.record_reshard(8, 4);
        let s = r.summary();
        assert_eq!(s.rejected, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.routed_jobs, 2);
        assert_eq!(s.shards_skipped, 5);
        assert_eq!(s.reshards, 1);
        assert_eq!(s.last_reshard, Some((8, 4)));
    }

    #[test]
    fn deadline_sheds_count_per_stage_and_export() {
        let mut r = Recorder::default();
        r.record_deadline(DeadlineStage::Admission);
        r.record_deadline(DeadlineStage::Admission);
        r.record_deadline(DeadlineStage::Batch);
        r.record_deadline(DeadlineStage::Dispatch);
        let s = r.summary();
        assert_eq!(s.deadline_admission, 2);
        assert_eq!(s.deadline_batch, 1);
        assert_eq!(s.deadline_dispatch, 1);
        assert_eq!(s.rejected, 0, "deadline sheds are not admission rejects");
        let parsed = crate::telemetry::json::parse(&s.to_value().to_json_pretty()).unwrap();
        let d = parsed.get("deadline").unwrap();
        assert_eq!(d.get("admission").and_then(Value::as_u64), Some(2));
        assert_eq!(d.get("batch").and_then(Value::as_u64), Some(1));
        assert_eq!(d.get("dispatch").and_then(Value::as_u64), Some(1));
    }

    #[test]
    fn reshard_does_not_perturb_prepare_accounting() {
        let mut r = Recorder::default();
        r.record_prepare(&PrepareCost {
            wall: Duration::from_millis(10),
            resident_bytes: 100,
        });
        r.record_prepare_hit();
        r.record_reshard(4, 2);
        let s = r.summary();
        assert_eq!(s.prepares, 1, "a rebuild is not a cache miss");
        assert!((s.prepare_hit_rate - 0.5).abs() < 1e-12);
        assert!((s.mean_prepare_s - 0.010).abs() < 1e-9, "rebuilds must not skew the mean");
        assert_eq!(s.reshards, 1);
    }

    #[test]
    fn shard_accounting_aggregates() {
        let mut r = Recorder::default();
        r.record_shards(&ShardRunStats {
            shards: 4,
            shard_nnz: vec![10, 10, 10, 10],
            shard_latency: vec![Duration::from_millis(2); 4],
            imbalance: 1.1,
        });
        r.record_shards(&ShardRunStats {
            shards: 2,
            shard_nnz: vec![30, 10],
            shard_latency: vec![Duration::from_millis(6), Duration::from_millis(1)],
            imbalance: 1.5,
        });
        let s = r.summary();
        assert_eq!(s.shard_execs, 2);
        assert!((s.mean_shards - 3.0).abs() < 1e-12);
        assert!((s.mean_shard_imbalance - 1.3).abs() < 1e-12);
        assert!((s.max_shard_imbalance - 1.5).abs() < 1e-12);
        assert!((s.mean_shard_makespan_s - 0.004).abs() < 1e-9);
    }

    #[test]
    fn backend_attribution_counts_per_name() {
        let mut r = Recorder::default();
        r.record(tb(1, 10, "native"));
        r.record(tb(2, 10, "functional"));
        r.record(tb(3, 10, "native"));
        let s = r.summary();
        assert_eq!(s.backends, vec![("functional", 1), ("native", 2)]);
        // Latency percentiles ride along per backend, same name order.
        assert_eq!(s.backend_latency.len(), 2);
        assert_eq!(s.backend_latency[0].0, "functional");
        assert_eq!(s.backend_latency[1].0, "native");
        assert!((s.backend_latency[0].1.p50 - 0.002).abs() / 0.002 < 0.045);
        // Native served 1 ms and 3 ms; p50 rank 1 of 2 -> ~3 ms.
        assert!((s.backend_latency[1].1.p50 - 0.003).abs() / 0.003 < 0.045);
    }

    #[test]
    fn image_breakdown_attributes_load_per_image() {
        let mut r = Recorder::default();
        let mut t7 = t(2, 100);
        t7.image = 7;
        let mut t3 = t(4, 50);
        t3.image = 3;
        r.record(t7);
        r.record(t3);
        r.record(t7);
        let s = r.summary();
        assert_eq!(s.images.len(), 2);
        assert_eq!(s.images[0].image, 3, "sorted by image id");
        assert_eq!(s.images[0].requests, 1);
        assert_eq!(s.images[0].flops, 50);
        assert_eq!(s.images[1].image, 7);
        assert_eq!(s.images[1].requests, 2);
        assert_eq!(s.images[1].flops, 200);
        assert!((s.images[1].sum_latency_s - 0.004).abs() < 1e-9);
    }

    #[test]
    fn summary_exports_stage_percentiles_as_json() {
        let mut r = Recorder::default();
        for ms in [1u64, 2, 3, 4, 5, 6, 7, 8, 9, 100] {
            r.record(t(ms, 10));
        }
        r.record_batch(10);
        let v = r.summary().to_value();
        let text = v.to_json_pretty();
        let parsed = crate::telemetry::json::parse(&text).unwrap();
        assert_eq!(parsed.get("requests").and_then(Value::as_u64), Some(10));
        let exec = parsed.get("stages").and_then(|s| s.get("exec")).unwrap();
        let p99 = exec.get("p99_s").and_then(Value::as_f64).unwrap();
        assert!(p99 > 0.0, "per-stage p99 must be exported: {text}");
        let queue = parsed.get("stages").and_then(|s| s.get("queue")).unwrap();
        assert!(queue.get("p50_s").is_some());
        assert!(queue.get("mean_s").is_some());
        let backends = parsed.get("backends").and_then(Value::as_arr).unwrap();
        assert_eq!(backends.len(), 1);
        assert_eq!(backends[0].get("backend").and_then(Value::as_str), Some("test"));
        assert!(backends[0].get("p95_s").is_some());
        let images = parsed.get("images").and_then(Value::as_arr).unwrap();
        assert_eq!(images[0].get("requests").and_then(Value::as_u64), Some(10));
    }
}
