//! Cycle-level streaming simulator (paper §3.1, §3.5 and the §4.1 in-house
//! simulator: "we model the computing time and memory accessing time and
//! record the larger one as the processing time at each stage").
//!
//! The accelerator is a pipeline of stages per (i, j) iteration of
//! Algorithm 1:
//!
//! ```text
//!   Read Ptr ─┐
//!   Read A  ──┤                      ┌─ Collect C ─ Comp C ─ Write C
//!   Read B  ──┴─► PEG 0..7 (PEs) ────┘        ▲
//!               (FIFO chain broadcast)     Read C_in
//! ```
//!
//! Double buffering overlaps window `j+1`'s B stream with window `j`'s PE
//! region, and the C tail (Collect/Comp/Write + Read C_in) overlaps the next
//! i-slice's ramp; each group contributes `max(stage times)` — the streaming
//! model of §4.1 — plus explicit fill/drain terms.

use super::config::AcceleratorConfig;
use crate::sched::ScheduledMatrix;

/// Where a simulated run spent its cycles (per i-slice, before the N/N0
/// multiplier).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageBreakdown {
    /// C scratchpad initialization (Algorithm 1 line 2).
    pub init_c: u64,
    /// B window streaming that could NOT be hidden behind PE compute.
    pub stream_b_exposed: u64,
    /// PE region (the II=1 pipeline; includes bubbles).
    pub pe: u64,
    /// A-stream bandwidth stall (scheduled slots arriving slower than
    /// 1/cycle/PE — only possible on bandwidth-starved configs).
    pub a_stall: u64,
    /// Comp-C + C_in/C_out streaming tail.
    pub tail: u64,
    /// Pipeline fill/drain + FIFO-chain skew.
    pub fill: u64,
}

impl StageBreakdown {
    /// Sum of all components.
    pub fn total(&self) -> u64 {
        self.init_c + self.stream_b_exposed + self.pe + self.a_stall + self.tail + self.fill
    }
}

/// Result of simulating one SpMM on one accelerator config.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Total cycles including setup.
    pub cycles: u64,
    /// Wall-clock seconds at the config's frequency.
    pub seconds: f64,
    /// Problem size in FLOP (2·NNZ·N + 3·M·N, §4.2.1).
    pub flops: u64,
    /// Achieved throughput in GFLOP/s.
    pub gflops: f64,
    /// Off-chip traffic in bytes (A slots + B + C_in + C_out + Q).
    pub hbm_bytes: u64,
    /// Per-i-slice stage breakdown (diagnostics / ablations).
    pub breakdown: StageBreakdown,
    /// Number of i-slices (N / N0, ceil).
    pub n_slices: u64,
}

/// Problem size in FLOP as the paper counts it (§4.2.1): 2 FLOP per
/// non-zero per B column for A×B, plus `alpha*AB + beta*C` element-wise
/// (2 mul + 1 add per C element).
pub fn problem_flops(nnz: usize, m: usize, n: usize) -> u64 {
    2 * nnz as u64 * n as u64 + 3 * m as u64 * n as u64
}

/// Simulate one SpMM (`C = αA×B + βC` with B of width `n`) on `cfg`.
///
/// The scheduled image must have been preprocessed with `p == cfg.p()` and
/// `k0 == cfg.k0` (enforced by assert — the HFlex runtime guarantees it).
pub fn simulate(sm: &ScheduledMatrix, cfg: &AcceleratorConfig, n: usize) -> SimReport {
    assert_eq!(sm.p, cfg.p(), "image scheduled for wrong PE count");
    assert_eq!(sm.k0, cfg.k0, "image scheduled for wrong window size");
    simulate_unchecked(sm, cfg, n)
}

/// Simulation core without the config-match guard (used by ablations that
/// deliberately run variant configs, e.g. Table 1's P=1 / N0=1 points).
pub fn simulate_unchecked(sm: &ScheduledMatrix, cfg: &AcceleratorConfig, n: usize) -> SimReport {
    let n = n.max(1);
    let n_slices = n.div_ceil(cfg.n0) as u64;
    let rows_per_pe = sm.rows_per_pe() as u64;
    let bpc = cfg.channel_bytes_per_cycle();

    let mut bd = StageBreakdown::default();

    // --- Line 2: C scratchpad init, all PEs parallel, F_B-style write width.
    bd.init_c = rows_per_pe;

    // --- Window loop (Eq. 3): stream B ‖ PE region, double-buffered.
    // B window bytes: actual window width × N0 lanes × 4 B, over the B
    // channel group (the last window of a K not divisible by K0 is short).
    let window_width = |j: usize| -> u64 {
        let base = j * sm.k0;
        sm.k0.min(sm.k.saturating_sub(base)) as u64
    };

    let mut pe_cycles_total = 0u64;
    let mut a_stall_total = 0u64;
    let mut exposed_b_total = 0u64;
    let mut prev_pe_region = 0u64; // for double-buffer overlap
    let mut b_bytes_total = 0u64;

    for (j, ws) in sm.window_stats.iter().enumerate() {
        let b_window_bytes = window_width(j) * (cfg.n0 * 4) as u64;
        b_bytes_total += b_window_bytes;
        let t_stream_b_bw = cfg.stream_cycles(b_window_bytes, cfg.channels.b);
        // On-chip write port bound (Eq. 7): width / (2·F_B).
        let t_stream_b_port = window_width(j).div_ceil(2 * cfg.f_b as u64);
        let t_stream_b = t_stream_b_bw.max(t_stream_b_port);
        // A-stream feed rate: each of the `a` channels feeds P / a PEs at
        // 8 B/slot; stall only if demand exceeds supply.
        let pes_per_a_channel = (sm.p as f64 / cfg.channels.a as f64).max(1.0);
        let demand_bytes_per_cycle = pes_per_a_channel * 8.0;
        let a_slowdown = (demand_bytes_per_cycle / bpc).max(1.0);
        let t_pe = (ws.max_cycles as f64 * a_slowdown).ceil() as u64;
        a_stall_total += t_pe - ws.max_cycles;

        // Double buffering: window j's B stream overlaps window j-1's PE
        // region (and, across slices, window 0's stream overlaps the
        // previous slice's tail — its cost appears once, in setup).
        let exposed = if j == 0 {
            if n_slices == 1 {
                t_stream_b
            } else {
                0 // prefetched during the previous slice's tail
            }
        } else {
            t_stream_b.saturating_sub(prev_pe_region)
        };
        exposed_b_total += exposed;
        pe_cycles_total += t_pe;
        prev_pe_region = t_pe;
    }
    bd.pe = pe_cycles_total;
    bd.a_stall = a_stall_total;
    bd.stream_b_exposed = exposed_b_total;

    // --- Line 13 tail: Comp C (Eq. 9: M / F_C) ‖ C_in read ‖ C_out write.
    // The Collect/Comp/Write chain is FIFO-coupled to the PEs (§3.5(4),
    // "HLS schedules some steps of (7) to be processed concurrently"), so
    // slice i's tail overlaps slice i+1's init/stream/PE ramp; only the
    // portion exceeding the next slice's core is exposed (plus one full
    // drain at the very end).
    let t_comp_c = (sm.m as u64).div_ceil(cfg.f_c as u64);
    let c_slice_bytes = (sm.m * cfg.n0 * 4) as u64;
    let t_c_in = cfg.stream_cycles(c_slice_bytes, cfg.channels.c_in);
    let t_c_out = cfg.stream_cycles(c_slice_bytes, cfg.channels.c_out);
    let t_tail = t_comp_c.max(t_c_in).max(t_c_out);

    // --- Fill/drain: PE pipeline depth per window + FIFO chain skew across
    // PEGs (chain-based broadcast, §3.1.1; depth-8 FIFOs tolerate 8 cycles
    // of skew per hop).
    let fifo_skew = (cfg.pegs as u64).saturating_sub(1) * cfg.fifo_depth as u64;
    bd.fill = sm.num_windows as u64 * cfg.pipeline_depth as u64 + fifo_skew;

    let per_slice_core = bd.init_c + bd.stream_b_exposed + bd.pe + bd.a_stall + bd.fill;
    bd.tail = t_tail.saturating_sub(per_slice_core); // exposed tail per slice
    let per_slice = bd.total();
    let cycles = cfg.setup_cycles + per_slice * n_slices + t_tail;

    // --- Off-chip traffic (Fig. 9's numerator is the *algorithmic* bytes;
    // this is the *actual* streamed bytes including bubbles and repeats).
    let a_bytes = sm.a_stream_bytes(); // re-streamed every i-slice
    let q_bytes: u64 = sm
        .streams
        .iter()
        .map(|s| (s.q.entries().len() * 4) as u64)
        .sum();
    let b_bytes = b_bytes_total;
    let c_bytes = 2 * c_slice_bytes;
    let hbm_bytes = (a_bytes + q_bytes + b_bytes + c_bytes) * n_slices;

    let seconds = cfg.seconds(cycles);
    let flops = problem_flops(sm.nnz, sm.m, n);
    SimReport {
        cycles,
        seconds,
        flops,
        gflops: flops as f64 / seconds / 1e9,
        hbm_bytes,
        breakdown: bd,
        n_slices,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::analytical;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};

    fn image(m: usize, k: usize, density: f64, seed: u64) -> ScheduledMatrix {
        let mut rng = Rng::new(seed);
        let coo = gen::random_uniform(m, k, density, &mut rng);
        let cfg = AcceleratorConfig::sextans_u280();
        preprocess(&coo, cfg.p(), cfg.k0, cfg.d)
    }

    #[test]
    fn more_columns_cost_more_slices() {
        let cfg = AcceleratorConfig::sextans_u280();
        let sm = image(2048, 2048, 0.01, 1);
        let r8 = simulate(&sm, &cfg, 8);
        let r512 = simulate(&sm, &cfg, 512);
        assert_eq!(r8.n_slices, 1);
        assert_eq!(r512.n_slices, 64);
        // Setup cycles amortize, so the ratio lands between ~10x and 64x.
        assert!(r512.cycles > r8.cycles * 10);
        assert!(r512.cycles < r8.cycles * 64);
    }

    #[test]
    fn throughput_saturates_with_problem_size() {
        // Paper Fig. 7a: throughput rises with problem size then saturates
        // below peak.
        let cfg = AcceleratorConfig::sextans_u280();
        let small = simulate(&image(256, 256, 0.02, 2), &cfg, 8);
        let large = simulate(&image(32_768, 32_768, 0.002, 3), &cfg, 512);
        assert!(large.gflops > 4.0 * small.gflops);
        assert!(large.gflops < cfg.datapath_roof_gflops());
    }

    #[test]
    fn cycles_close_to_analytical_model() {
        // §3.6.1's closed form should track the simulator within ~2x on
        // well-balanced uniform matrices (the closed form ignores bubbles,
        // imbalance, fill and setup).
        let cfg = AcceleratorConfig::sextans_u280();
        let sm = image(8192, 8192, 0.004, 4);
        let sim = simulate(&sm, &cfg, 128);
        let ana = analytical::cycles(&cfg, sm.m, sm.k, sm.nnz, 128);
        let ratio = sim.cycles as f64 / ana as f64;
        assert!((0.8..2.5).contains(&ratio), "sim/analytical = {ratio}");
    }

    #[test]
    fn sextans_p_is_faster_than_u280() {
        let sm = image(8192, 8192, 0.004, 5);
        let u280 = simulate(&sm, &AcceleratorConfig::sextans_u280(), 128);
        let p = simulate(&sm, &AcceleratorConfig::sextans_p(), 128);
        assert!(p.seconds < u280.seconds, "{} !< {}", p.seconds, u280.seconds);
    }

    #[test]
    fn breakdown_sums_to_slice_cycles() {
        let cfg = AcceleratorConfig::sextans_u280();
        let sm = image(1024, 1024, 0.01, 6);
        let r = simulate(&sm, &cfg, 64);
        // cycles = setup + per-slice breakdown × slices + final tail drain.
        let core = cfg.setup_cycles + r.breakdown.total() * r.n_slices;
        assert!(r.cycles >= core, "{} < {core}", r.cycles);
        // Final drain is bounded by one Comp-C pass.
        let max_tail = (sm.m as u64).div_ceil(cfg.f_c as u64)
            + cfg.stream_cycles((sm.m * cfg.n0 * 4) as u64, 1);
        assert!(r.cycles <= core + max_tail, "{} > {core} + {max_tail}", r.cycles);
    }

    #[test]
    fn flops_formula() {
        assert_eq!(problem_flops(100, 10, 8), 2 * 100 * 8 + 3 * 10 * 8);
    }

    #[test]
    fn hbm_bytes_scale_with_slices() {
        let cfg = AcceleratorConfig::sextans_u280();
        let sm = image(1024, 1024, 0.01, 7);
        let r1 = simulate(&sm, &cfg, 8);
        let r4 = simulate(&sm, &cfg, 32);
        assert_eq!(r4.hbm_bytes, 4 * r1.hbm_bytes);
    }
}
