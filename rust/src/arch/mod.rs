//! The accelerator model: configuration (§3.1), functional execution
//! (§3.2 datapath semantics), cycle-level streaming simulation (§3.5/§4.1),
//! and the FPGA resource model (§3.6.2 / Table 4).

pub mod config;
pub mod functional;
pub mod resources;
pub mod simulator;

pub use config::AcceleratorConfig;
pub use simulator::{simulate, simulate_unchecked, SimReport};
