//! Accelerator configuration — the "synthesis-time" constants of §3.1/§3.2
//! and Table 3. An [`AcceleratorConfig`] is immutable once built: the HFlex
//! contract (§3.4) is that *problems* change, configurations do not.

/// HBM channel assignment (paper §3.1.1): "1 HBM channel to pointers Q,
/// 4 channels to matrix B, 8 to matrix A, 8 to C_in, and 8 to C_out."
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HbmAssignment {
    /// Channels carrying the Q pointer stream.
    pub q: usize,
    /// Channels streaming B windows.
    pub b: usize,
    /// Channels streaming scheduled A slots.
    pub a: usize,
    /// Channels streaming C_in.
    pub c_in: usize,
    /// Channels streaming C_out.
    pub c_out: usize,
}

impl HbmAssignment {
    /// Total channels consumed (U280 exposes 32 pseudo-channels; the paper
    /// uses 29 — M-AXI limits, §4.2.3).
    pub fn total(&self) -> usize {
        self.q + self.b + self.a + self.c_in + self.c_out
    }
}

/// Full accelerator configuration. Defaults mirror the U280 prototype.
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// Human-readable platform name (Table 3 row).
    pub name: &'static str,
    /// Processing-engine groups (paper: 8).
    pub pegs: usize,
    /// PEs per group (paper: 8) — total P = pegs * pes_per_peg.
    pub pes_per_peg: usize,
    /// PUs per PE = dense lanes shared per non-zero (paper N0 = 8).
    pub n0: usize,
    /// B window size K0 (paper: 4096).
    pub k0: usize,
    /// C scratchpad depth per PE (paper: 12,288 URAM entries).
    pub c_depth: usize,
    /// RAW dependency distance D of the FP accumulator (paper: "7 to 10
    /// cycles depending on specific FPGAs"; U280 float add ≈ 10).
    pub d: usize,
    /// Pipeline depth for one A element ("the latency for processing one A
    /// element is 15 cycles on a Xilinx U280", §3.5(3)).
    pub pipeline_depth: usize,
    /// BRAM partition factor for the B buffer (paper F_B = 4; dual-ported,
    /// so 2*F_B elements land per cycle, Eq. 7).
    pub f_b: usize,
    /// Comp-C parallel factor (paper F_C = 16, Eq. 9).
    pub f_c: usize,
    /// Inter-PE FIFO depth (paper §3.5(4): 8).
    pub fifo_depth: usize,
    /// Clock frequency in MHz (Sextans: 189; Sextans-P: 350).
    pub freq_mhz: f64,
    /// Total HBM bandwidth in GB/s (U280: 460; Sextans-P: 900).
    pub hbm_gbps: f64,
    /// Channel assignment.
    pub channels: HbmAssignment,
    /// Total pseudo-channels on the board (bandwidth per channel =
    /// hbm_gbps / board_channels).
    pub board_channels: usize,
    /// Fixed per-invocation setup cycles (C-scratchpad arming, control;
    /// amortized on large problems, visible below ~1e6 FLOP — §4.2.1).
    pub setup_cycles: u64,
    /// Board power in watts (Table 3; measured via xbutil for U280).
    pub power_w: f64,
}

impl AcceleratorConfig {
    /// The U280 FPGA prototype (Table 3 row "SEXTANS").
    pub fn sextans_u280() -> Self {
        AcceleratorConfig {
            name: "Sextans",
            pegs: 8,
            pes_per_peg: 8,
            n0: 8,
            k0: 4096,
            c_depth: 12_288,
            d: 10,
            pipeline_depth: 15,
            f_b: 4,
            f_c: 16,
            fifo_depth: 8,
            freq_mhz: 189.0,
            hbm_gbps: 460.0,
            channels: HbmAssignment { q: 1, b: 4, a: 8, c_in: 8, c_out: 8 },
            board_channels: 32,
            setup_cycles: 4_000,
            power_w: 52.0,
        }
    }

    /// The projected prototype (Table 3 row "SEXTANS-P"): V100-class
    /// bandwidth (900 GB/s) and AutoBridge-class frequency (350 MHz).
    pub fn sextans_p() -> Self {
        AcceleratorConfig {
            name: "Sextans-P",
            freq_mhz: 350.0,
            hbm_gbps: 900.0,
            power_w: 96.0, // P = C·V²·f scaling of the measured 52 W (§4.1)
            ..Self::sextans_u280()
        }
    }

    /// Total PE count P.
    #[inline]
    pub fn p(&self) -> usize {
        self.pegs * self.pes_per_peg
    }

    /// Bytes per cycle delivered by one HBM pseudo-channel.
    pub fn channel_bytes_per_cycle(&self) -> f64 {
        let per_channel_gbps = self.hbm_gbps / self.board_channels as f64;
        per_channel_gbps * 1e9 / (self.freq_mhz * 1e6)
    }

    /// Cycles to stream `bytes` over `nch` channels (bandwidth-bound).
    pub fn stream_cycles(&self, bytes: u64, nch: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let rate = self.channel_bytes_per_cycle() * nch as f64;
        (bytes as f64 / rate).ceil() as u64
    }

    /// Seconds for a cycle count at this clock.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e6)
    }

    /// Datapath roof in GFLOP/s: P PEs × N0 PUs × 2 FLOP per cycle.
    /// U280: 64·8·2·189 MHz = 193.5; Table 3's *achieved* peak of 181.1
    /// (93.6% of roof) sits just under it — the gap is Comp-C tail,
    /// fill/drain and B-stream exposure on the best-case matrix.
    pub fn datapath_roof_gflops(&self) -> f64 {
        (self.p() * self.n0 * 2) as f64 * self.freq_mhz / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_matches_paper_constants() {
        let c = AcceleratorConfig::sextans_u280();
        assert_eq!(c.p(), 64);
        assert_eq!(c.channels.total(), 29);
        assert_eq!(c.k0, 4096);
        assert_eq!(c.c_depth, 12_288);
        // Table 3's achieved peak (181.1 GFLOP/s) must sit just under the
        // datapath roof: consistency check on the constants.
        let roof = c.datapath_roof_gflops();
        assert!(roof >= 181.1 && 181.1 >= 0.90 * roof, "roof = {roof}");
    }

    #[test]
    fn sextans_p_matches_paper_constants() {
        let c = AcceleratorConfig::sextans_p();
        assert_eq!(c.freq_mhz, 350.0);
        assert_eq!(c.hbm_gbps, 900.0);
        // Table 3: achieved peak 343.6 GFLOP/s under the 350 MHz roof
        // 64·8·2·350 = 358.4 (95.9%).
        let roof = c.datapath_roof_gflops();
        assert!(roof >= 343.6 && 343.6 >= 0.90 * roof, "roof = {roof}");
    }

    #[test]
    fn channel_rate_u280() {
        let c = AcceleratorConfig::sextans_u280();
        // 460/32 = 14.375 GB/s per channel (paper §2.3) at 189 MHz ≈ 76 B/cyc.
        let bpc = c.channel_bytes_per_cycle();
        assert!((bpc - 76.06).abs() < 0.5, "bytes/cycle = {bpc}");
    }

    #[test]
    fn stream_cycles_rounds_up() {
        let c = AcceleratorConfig::sextans_u280();
        assert_eq!(c.stream_cycles(0, 4), 0);
        assert!(c.stream_cycles(1, 1) >= 1);
        let one_kb = c.stream_cycles(1024, 1);
        let four_ch = c.stream_cycles(1024, 4);
        assert!(four_ch <= one_kb.div_ceil(4) + 1);
    }

    #[test]
    fn config_is_cloneable_and_comparable() {
        let a = AcceleratorConfig::sextans_u280();
        let b = a.clone();
        assert_eq!(a, b);
        assert_ne!(AcceleratorConfig::sextans_p(), a);
    }
}
