//! FPGA resource model (paper §3.6.2, Table 4) and the Fig. 6 floorplan.
//!
//! Component-based estimate: each architectural unit contributes a
//! documented cost; the totals land on Table 4's measured utilization for
//! the paper configuration (asserted in tests), and scale meaningfully for
//! ablation configs (e.g. halving PEGs roughly halves BRAM/DSP).

use super::config::AcceleratorConfig;

/// Available resources on a Xilinx U280 (Table 4 "Available" column).
#[derive(Clone, Copy, Debug)]
pub struct Board {
    /// BRAM blocks (18 Kb).
    pub bram: u64,
    /// DSP48 slices.
    pub dsp: u64,
    /// Flip-flops.
    pub ff: u64,
    /// LUTs.
    pub lut: u64,
    /// URAM blocks (288 Kb).
    pub uram: u64,
}

/// The U280's budget.
pub const U280: Board = Board {
    bram: 4032,
    dsp: 9024,
    ff: 2_607_360,
    lut: 1_303_680,
    uram: 960,
};

/// Estimated usage for one config.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceReport {
    /// BRAM blocks.
    pub bram: u64,
    /// DSP48 slices.
    pub dsp: u64,
    /// Flip-flops.
    pub ff: u64,
    /// LUTs.
    pub lut: u64,
    /// URAM blocks.
    pub uram: u64,
}

impl ResourceReport {
    /// Utilization percentages against a board.
    pub fn utilization(&self, board: &Board) -> [(String, u64, u64, f64); 5] {
        let pct = |used: u64, avail: u64| 100.0 * used as f64 / avail as f64;
        [
            ("BRAM".into(), self.bram, board.bram, pct(self.bram, board.bram)),
            ("DSP48".into(), self.dsp, board.dsp, pct(self.dsp, board.dsp)),
            ("FF".into(), self.ff, board.ff, pct(self.ff, board.ff)),
            ("LUT".into(), self.lut, board.lut, pct(self.lut, board.lut)),
            ("URAM".into(), self.uram, board.uram, pct(self.uram, board.uram)),
        ]
    }

    /// True if the design fits the board.
    pub fn fits(&self, board: &Board) -> bool {
        self.bram <= board.bram
            && self.dsp <= board.dsp
            && self.ff <= board.ff
            && self.lut <= board.lut
            && self.uram <= board.uram
    }
}

/// Estimate resources for `cfg`.
pub fn estimate(cfg: &AcceleratorConfig) -> ResourceReport {
    let p = cfg.p() as u64;
    let n0 = cfg.n0 as u64;
    let pegs = cfg.pegs as u64;
    let channels = cfg.channels.total() as u64;

    // --- BRAM (§3.6.2): B window buffer = (K0/1024)*2 blocks per lane,
    // N0 lanes per PE, one buffer shared between 2 PEs (dual-port).
    let b_blocks_per_lane = (cfg.k0 as u64).div_ceil(1024) * 2;
    let bram_b = b_blocks_per_lane * n0 * p / 2; // paper: 8*8*64/2 = 2048
    // FIFO chain + collect/comp staging: ~14 blocks per PEG.
    let bram_fifo = pegs * 14;
    // AXI/HBM interface buffering: ~32 blocks per channel (512-deep, 512-bit).
    let bram_axi = channels * 32;
    let bram = bram_b + bram_fifo + bram_axi; // U280 cfg: 2048+112+928 = 3088 ≈ 3086

    // --- URAM (§3.6.2): C scratchpad, depth `c_depth`, 2 FP32 per 72-bit
    // entry, N0 lanes: c_depth/4096 * N0/2 blocks per PE.
    let uram = (cfg.c_depth as u64).div_ceil(4096) * n0 / 2 * p; // 3*4*64 = 768

    // --- DSP48: FP32 mul ≈ 3, FP32 add ≈ 2 DSPs per PU lane; Comp-C has
    // F_C × N0 lanes with mul+mul+add ≈ 8 DSPs each... calibrated: 5 per
    // PU MAC lane + Comp-C lanes + ~1.5% control overhead.
    let pu_lanes = p * n0;
    let compc_lanes = (cfg.f_c * cfg.n0) as u64;
    let dsp = pu_lanes * 5 + compc_lanes * 5 + 36; // 2560+640+36 = 3236 ≈ 3316

    // --- FF / LUT: per-PE datapath + per-PEG control + per-channel AXI,
    // constants calibrated to Table 4 (690,255 FF / 379,649 LUT).
    let ff = p * 8_700 + pegs * 6_200 + channels * 2_900 + 16_000;
    let lut = p * 4_700 + pegs * 3_100 + channels * 1_700 + 4_000;

    ResourceReport { bram, dsp, ff, lut, uram }
}

/// ASCII floorplan in the spirit of Fig. 6 (SLR-stacked U280 layout).
pub fn floorplan(cfg: &AcceleratorConfig) -> String {
    let mut s = String::new();
    s.push_str("+--------------------- Xilinx U280 ---------------------+\n");
    s.push_str("| SLR2 |  PEG 6  |  PEG 7  |  CompC  |  C in/out (HBM)  |\n");
    s.push_str("+------+---------+---------+---------+------------------+\n");
    s.push_str("| SLR1 |  PEG 2  |  PEG 3  |  PEG 4  |  PEG 5  | B rd   |\n");
    s.push_str("+------+---------+---------+---------+---------+--------+\n");
    s.push_str("| SLR0 |  PEG 0  |  PEG 1  |  A rd x8  |  Ptr rd | HBM  |\n");
    s.push_str("+--------------------------------------------------------+\n");
    s.push_str(&format!(
        "  {} PEGs x {} PEs x {} PUs | K0={} | C depth={} | {} HBM ch\n",
        cfg.pegs,
        cfg.pes_per_peg,
        cfg.n0,
        cfg.k0,
        cfg.c_depth,
        cfg.channels.total()
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_estimate_matches_table4() {
        // Table 4: BRAM 3086 (76%), DSP 3316 (36%), FF 690,255 (26%),
        // LUT 379,649 (29%), URAM 768 (80%). Component model should land
        // within ~5% on each.
        let r = estimate(&AcceleratorConfig::sextans_u280());
        let close = |got: u64, want: u64, tol: f64| {
            (got as f64 - want as f64).abs() / want as f64 <= tol
        };
        assert!(close(r.bram, 3086, 0.05), "bram {}", r.bram);
        assert!(close(r.dsp, 3316, 0.05), "dsp {}", r.dsp);
        assert!(close(r.ff, 690_255, 0.05), "ff {}", r.ff);
        assert!(close(r.lut, 379_649, 0.05), "lut {}", r.lut);
        assert_eq!(r.uram, 768);
    }

    #[test]
    fn u280_estimate_fits_board() {
        let r = estimate(&AcceleratorConfig::sextans_u280());
        assert!(r.fits(&U280));
        // URAM utilization 80% (paper §3.6.2).
        let util = r.utilization(&U280);
        let uram_pct = util[4].3;
        assert!((uram_pct - 80.0).abs() < 0.5, "uram {uram_pct}%");
    }

    #[test]
    fn resources_scale_with_pegs() {
        let full = estimate(&AcceleratorConfig::sextans_u280());
        let mut half_cfg = AcceleratorConfig::sextans_u280();
        half_cfg.pegs = 4;
        let half = estimate(&half_cfg);
        assert!(half.bram < full.bram);
        assert!(half.dsp < full.dsp);
        assert!((half.uram as f64 / full.uram as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn floorplan_mentions_all_pegs_and_channels() {
        let cfg = AcceleratorConfig::sextans_u280();
        let f = floorplan(&cfg);
        assert!(f.contains("8 PEGs x 8 PEs x 8 PUs"));
        assert!(f.contains("29 HBM ch"));
    }
}
