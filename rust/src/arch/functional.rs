//! Functional simulator: exact FP32 execution of a preprocessed matrix
//! through the PE datapath order.
//!
//! Numerics follow the hardware exactly: each PE consumes its scheduled
//! slot stream in issue order, bubbles multiply-accumulate 0.0, and the
//! Comp-C stage applies `C_out = α·C_AB + β·C_in` element-wise. Because FP
//! addition is non-associative, results differ from a naive row-major CSR
//! SpMM only by reassociation — the integration tests assert allclose, and
//! the PJRT path (same slot order) matches this simulator bit-for-bit
//! modulo XLA's FMA contraction.

use crate::sched::{decode, ScheduledMatrix};

/// Execute `C = alpha * A @ B + beta * C` where A is the scheduled image.
///
/// * `b` — dense B, row-major `k x n`.
/// * `c` — dense C in/out, row-major `m x n`.
///
/// Panics on shape mismatch (programming error, not data error).
pub fn execute(sm: &ScheduledMatrix, b: &[f32], c: &mut [f32], n: usize, alpha: f32, beta: f32) {
    assert_eq!(b.len(), sm.k * n, "B must be k x n");
    assert_eq!(c.len(), sm.m * n, "C must be m x n");

    // C_AB accumulator — the union of all PE scratchpads across i-slices.
    let mut ab = vec![0f32; sm.m * n];

    for (pe, stream) in sm.streams.iter().enumerate() {
        for j in 0..sm.num_windows {
            let col_base = j * sm.k0;
            for &word in &stream.encoded[stream.q.window_range(j)] {
                let nz = decode(word);
                if nz.val == 0.0 {
                    continue; // bubble (or explicit zero: same arithmetic)
                }
                let gr = nz.row as usize * sm.p + pe;
                let gc = col_base + nz.col as usize;
                debug_assert!(gr < sm.m && gc < sm.k);
                let brow = &b[gc * n..gc * n + n];
                let crow = &mut ab[gr * n..gr * n + n];
                for q in 0..n {
                    crow[q] += nz.val * brow[q];
                }
            }
        }
    }

    // Comp-C stage (Eq. 1 third phase).
    for i in 0..c.len() {
        c[i] = alpha * ab[i] + beta * c[i];
    }
}

/// Convenience: allocate and return C_out (C_in = zeros, beta irrelevant).
pub fn execute_ab(sm: &ScheduledMatrix, b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0f32; sm.m * n];
    execute(sm, b, &mut c, n, 1.0, 0.0);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sched::preprocess;
    use crate::sched::preprocess::{preprocess_mode, ScheduleMode};
    use crate::sparse::{gen, rng::Rng, Coo};

    #[test]
    fn identity_matrix_passthrough() {
        let mut rng = Rng::new(1);
        let eye = gen::diagonal(16, &mut rng);
        // Replace random diagonal values with 1.0 for a true identity.
        let eye = Coo::new(16, 16, eye.rows, eye.cols, vec![1.0; 16]).unwrap();
        let sm = preprocess(&eye, 4, 8, 6);
        let b: Vec<f32> = (0..16 * 3).map(|i| i as f32).collect();
        let got = execute_ab(&sm, &b, 3);
        assert_eq!(got, b);
    }

    #[test]
    fn matches_coo_reference_small() {
        let mut rng = Rng::new(2);
        let a = gen::random_uniform(24, 40, 0.15, &mut rng);
        let sm = preprocess(&a, 4, 16, 8);
        let n = 4;
        let b: Vec<f32> = (0..40 * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..24 * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        a.spmm_reference(&b, &mut want, n, 1.5, -0.25);
        let mut got = c0;
        execute(&sm, &b, &mut got, n, 1.5, -0.25);
        prop::assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn schedule_mode_does_not_change_numerics() {
        let mut rng = Rng::new(3);
        let a = gen::power_law_rows(60, 50, 600, 1.2, &mut rng);
        let n = 8;
        let b: Vec<f32> = (0..50 * n).map(|_| rng.normal()).collect();
        let base = execute_ab(&preprocess(&a, 8, 16, 10), &b, n);
        for mode in [ScheduleMode::InOrderColMajor, ScheduleMode::InOrderRowMajor] {
            let alt = execute_ab(&preprocess_mode(&a, 8, 16, 10, mode), &b, n);
            prop::assert_allclose(&base, &alt, 1e-4, 1e-4).unwrap();
        }
    }

    #[test]
    fn alpha_beta_composition() {
        let mut rng = Rng::new(4);
        let a = gen::random_uniform(10, 10, 0.3, &mut rng);
        let sm = preprocess(&a, 2, 4, 4);
        let b: Vec<f32> = (0..10 * 2).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..10 * 2).map(|_| rng.normal()).collect();
        // alpha=0 kills A@B; beta=1 preserves C.
        let mut c = c0.clone();
        execute(&sm, &b, &mut c, 2, 0.0, 1.0);
        assert_eq!(c, c0);
        // beta=0 zeroes C_in contribution.
        let mut c1 = c0.clone();
        execute(&sm, &b, &mut c1, 2, 1.0, 0.0);
        let ab = execute_ab(&sm, &b, 2);
        assert_eq!(c1, ab);
    }

    #[test]
    fn functional_matches_reference_property() {
        prop::check("functional_vs_reference", 0xF0C, 24, |rng| {
            let m = 1 + rng.index(64);
            let k = 1 + rng.index(64);
            let n = 1 + rng.index(10);
            let a = gen::random_uniform(m, k, 0.05 + rng.f64() * 0.2, rng);
            let p = 1 + rng.index(8);
            let k0 = 1 + rng.index(32);
            let d = 1 + rng.index(12);
            let sm = preprocess(&a, p, k0, d);
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let alpha = rng.range_f32(-2.0, 2.0);
            let beta = rng.range_f32(-2.0, 2.0);
            let mut want = c0.clone();
            a.spmm_reference(&b, &mut want, n, alpha, beta);
            let mut got = c0;
            execute(&sm, &b, &mut got, n, alpha, beta);
            prop::assert_allclose(&got, &want, 2e-4, 2e-4)
        });
    }

    #[test]
    fn empty_matrix_gives_beta_c() {
        let a = Coo::empty(4, 4);
        let sm = preprocess(&a, 2, 2, 4);
        let b = vec![1.0; 8];
        let mut c = vec![2.0; 8];
        execute(&sm, &b, &mut c, 2, 5.0, 0.5);
        assert_eq!(c, vec![1.0; 8]);
    }
}
