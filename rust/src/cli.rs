//! Minimal CLI argument parser (offline substitute for `clap`).
//!
//! Grammar: `sextans <command> [--key value]... [--flag]... [positional]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// First non-flag token.
    pub command: String,
    /// `--key value` pairs and bare `--flag`s (value `"true"`).
    pub options: HashMap<String, String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Cli {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    cli.options.insert(key.to_string(), v);
                } else {
                    cli.options.insert(key.to_string(), "true".to_string());
                }
            } else if cli.command.is_empty() {
                cli.command = arg;
            } else {
                cli.positional.push(arg);
            }
        }
        cli
    }

    /// Parse the process arguments.
    pub fn from_env() -> Cli {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Boolean flag (present, or `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// usize option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// f32 option with default.
    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// u64 option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_and_options() {
        let c = parse("repro --all --out results table1");
        assert_eq!(c.command, "repro");
        assert!(c.flag("all"));
        assert_eq!(c.get("out"), Some("results"));
        assert_eq!(c.positional, vec!["table1"]);
    }

    #[test]
    fn key_equals_value() {
        let c = parse("run --n=64 --alpha=1.5");
        assert_eq!(c.get_usize("n", 0), 64);
        assert!((c.get_f32("alpha", 0.0) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn defaults_apply() {
        let c = parse("run");
        assert_eq!(c.get_usize("n", 8), 8);
        assert!(!c.flag("verbose"));
    }

    #[test]
    fn flag_before_value_option() {
        let c = parse("x --verbose --seed 42");
        assert!(c.flag("verbose"));
        assert_eq!(c.get_u64("seed", 0), 42);
    }
}
