//! Coordinate-list (COO) sparse matrix — the interchange format of the crate.
//!
//! The paper (§4.2.3) notes COO costs 12 bytes/non-zero (row, col, val at
//! 4 bytes each); Sextans' preprocessed format compresses this to 8 bytes
//! (see [`crate::sched::encode`]).

use anyhow::{bail, Result};

/// Sparse matrix in COO form. Entries are not required to be sorted, but
/// duplicates are disallowed by the constructors that check.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Coo {
    /// Number of rows (M).
    pub m: usize,
    /// Number of columns (K).
    pub k: usize,
    /// Row index per non-zero.
    pub rows: Vec<u32>,
    /// Column index per non-zero.
    pub cols: Vec<u32>,
    /// Value per non-zero.
    pub vals: Vec<f32>,
}

impl Coo {
    /// Build and validate bounds (O(nnz)). Does not check duplicates.
    pub fn new(m: usize, k: usize, rows: Vec<u32>, cols: Vec<u32>, vals: Vec<f32>) -> Result<Self> {
        if rows.len() != cols.len() || rows.len() != vals.len() {
            bail!(
                "COO triplet length mismatch: rows={} cols={} vals={}",
                rows.len(),
                cols.len(),
                vals.len()
            );
        }
        if let Some(&r) = rows.iter().max() {
            if r as usize >= m {
                bail!("row index {r} out of bounds for m={m}");
            }
        }
        if let Some(&c) = cols.iter().max() {
            if c as usize >= k {
                bail!("col index {c} out of bounds for k={k}");
            }
        }
        Ok(Coo { m, k, rows, cols, vals })
    }

    /// Empty matrix of the given shape.
    pub fn empty(m: usize, k: usize) -> Self {
        Coo { m, k, rows: vec![], cols: vec![], vals: vec![] }
    }

    /// Number of stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Density = nnz / (m * k).
    pub fn density(&self) -> f64 {
        if self.m == 0 || self.k == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.m as f64 * self.k as f64)
    }

    /// Sort entries row-major (row, then col). Stable w.r.t. duplicates.
    pub fn sort_row_major(&mut self) {
        let mut idx: Vec<usize> = (0..self.nnz()).collect();
        idx.sort_by_key(|&i| (self.rows[i], self.cols[i]));
        self.permute(&idx);
    }

    /// Sort entries column-major (col, then row) — the order the Sextans
    /// scheduler consumes (outer-product-like processing, Eq. 5).
    pub fn sort_col_major(&mut self) {
        let mut idx: Vec<usize> = (0..self.nnz()).collect();
        idx.sort_by_key(|&i| (self.cols[i], self.rows[i]));
        self.permute(&idx);
    }

    fn permute(&mut self, idx: &[usize]) {
        self.rows = idx.iter().map(|&i| self.rows[i]).collect();
        self.cols = idx.iter().map(|&i| self.cols[i]).collect();
        self.vals = idx.iter().map(|&i| self.vals[i]).collect();
    }

    /// Sum duplicate (row, col) entries. Result is row-major sorted.
    pub fn sum_duplicates(&mut self) {
        if self.nnz() == 0 {
            return;
        }
        self.sort_row_major();
        let mut w = 0usize;
        for r in 1..self.nnz() {
            if self.rows[r] == self.rows[w] && self.cols[r] == self.cols[w] {
                self.vals[w] += self.vals[r];
            } else {
                w += 1;
                self.rows[w] = self.rows[r];
                self.cols[w] = self.cols[r];
                self.vals[w] = self.vals[r];
            }
        }
        self.rows.truncate(w + 1);
        self.cols.truncate(w + 1);
        self.vals.truncate(w + 1);
    }

    /// Drop explicit zeros.
    pub fn prune_zeros(&mut self) {
        let keep: Vec<usize> = (0..self.nnz()).filter(|&i| self.vals[i] != 0.0).collect();
        self.permute(&keep);
    }

    /// Transpose (O(nnz), swaps m/k and row/col).
    pub fn transpose(&self) -> Coo {
        Coo {
            m: self.k,
            k: self.m,
            rows: self.cols.clone(),
            cols: self.rows.clone(),
            vals: self.vals.clone(),
        }
    }

    /// Dense `C = alpha * A @ B + beta * C` reference (row-major B, C with
    /// `n` columns). The naive oracle everything else is checked against.
    pub fn spmm_reference(&self, b: &[f32], c: &mut [f32], n: usize, alpha: f32, beta: f32) {
        assert_eq!(b.len(), self.k * n, "B shape mismatch");
        assert_eq!(c.len(), self.m * n, "C shape mismatch");
        let mut ab = vec![0f32; self.m * n];
        for i in 0..self.nnz() {
            let (r, cl, v) = (self.rows[i] as usize, self.cols[i] as usize, self.vals[i]);
            let brow = &b[cl * n..cl * n + n];
            let crow = &mut ab[r * n..r * n + n];
            for q in 0..n {
                crow[q] += v * brow[q];
            }
        }
        for i in 0..c.len() {
            c[i] = alpha * ab[i] + beta * c[i];
        }
    }

    /// Max non-zeros in any single row (load-imbalance statistic, Fig. 1).
    pub fn max_row_nnz(&self) -> usize {
        let mut counts = vec![0usize; self.m];
        for &r in &self.rows {
            counts[r as usize] += 1;
        }
        counts.into_iter().max().unwrap_or(0)
    }

    /// Per-row non-zero counts.
    pub fn row_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.m];
        for &r in &self.rows {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Memory footprint of the COO representation in bytes (12 B/nnz).
    pub fn footprint_bytes(&self) -> usize {
        self.nnz() * 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Coo {
        // [[1, 0, 2], [0, 3, 0]]
        Coo::new(2, 3, vec![0, 0, 1], vec![0, 2, 1], vec![1.0, 2.0, 3.0]).unwrap()
    }

    #[test]
    fn new_validates_bounds() {
        assert!(Coo::new(2, 2, vec![2], vec![0], vec![1.0]).is_err());
        assert!(Coo::new(2, 2, vec![0], vec![2], vec![1.0]).is_err());
        assert!(Coo::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err());
    }

    #[test]
    fn nnz_and_density() {
        let a = small();
        assert_eq!(a.nnz(), 3);
        assert!((a.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spmm_reference_identity() {
        // A = I2 (as 2x2), B = [[1,2],[3,4]]
        let a = Coo::new(2, 2, vec![0, 1], vec![0, 1], vec![1.0, 1.0]).unwrap();
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let mut c = vec![0.0; 4];
        a.spmm_reference(&b, &mut c, 2, 1.0, 0.0);
        assert_eq!(c, b);
    }

    #[test]
    fn spmm_reference_alpha_beta() {
        let a = small();
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3x2
        let mut c = vec![10.0, 10.0, 10.0, 10.0]; // 2x2
        // A@B = [[1*1+2*1, 2*1],[3*0, 3*1]] = [[3,2],[0,3]]
        a.spmm_reference(&b, &mut c, 2, 2.0, 0.5);
        assert_eq!(c, vec![11.0, 9.0, 5.0, 11.0]);
    }

    #[test]
    fn sort_col_major_orders_by_column() {
        let mut a = small();
        a.sort_col_major();
        assert_eq!(a.cols, vec![0, 1, 2]);
        assert_eq!(a.vals, vec![1.0, 3.0, 2.0]);
    }

    #[test]
    fn sum_duplicates_merges() {
        let mut a =
            Coo::new(2, 2, vec![0, 0, 1], vec![1, 1, 0], vec![1.0, 2.0, 5.0]).unwrap();
        a.sum_duplicates();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.vals, vec![3.0, 5.0]);
    }

    #[test]
    fn prune_zeros_drops_explicit_zeros() {
        let mut a = Coo::new(1, 3, vec![0, 0, 0], vec![0, 1, 2], vec![1.0, 0.0, 2.0]).unwrap();
        a.prune_zeros();
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.cols, vec![0, 2]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let t = a.transpose().transpose();
        assert_eq!(a, t);
    }

    #[test]
    fn row_stats() {
        let a = small();
        assert_eq!(a.max_row_nnz(), 2);
        assert_eq!(a.row_counts(), vec![2, 1]);
    }
}
