//! The 200-matrix evaluation catalog (paper Table 2 analogue).
//!
//! 50 "SNAP-like" power-law graphs + 150 "SuiteSparse-like" matrices across
//! five structural families, with deterministic seeds. Statistic ranges
//! mirror Table 2: row/col 5–513,351, NNZ 10–~2×10⁷ (the paper's absolute
//! max of 3.7×10⁷ is represented by the `scale` knob: `Scale::Full`
//! includes the multi-million-nnz tail, `Scale::Ci` caps sizes so the whole
//! 1,400-SpMM sweep runs in CI time — the *distribution shape* is identical).

use super::coo::Coo;
use super::gen;
use super::rng::Rng;

/// Matrix family, mirroring the provenance split in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// SNAP-style social/web graph (R-MAT power law).
    SnapRmat,
    /// SuiteSparse FEM/structural (banded).
    SsBanded,
    /// SuiteSparse circuit (diagonal-dominant).
    SsCircuit,
    /// SuiteSparse random/optimization (uniform).
    SsUniform,
    /// SuiteSparse supernodal/block.
    SsBlock,
    /// Bipartite recommender-ish (Zipf rows).
    SsPowerRows,
}

impl Family {
    /// Provenance label used in reports.
    pub fn source(&self) -> &'static str {
        match self {
            Family::SnapRmat => "SNAP",
            _ => "SuiteSparse",
        }
    }
}

/// A catalog entry: everything needed to regenerate the matrix.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    /// Unique, stable name (used in reports and caches).
    pub name: String,
    /// Structural family.
    pub family: Family,
    /// Rows.
    pub m: usize,
    /// Columns.
    pub k: usize,
    /// Target non-zeros (generators may merge a few duplicates).
    pub nnz: usize,
    /// Deterministic seed.
    pub seed: u64,
}

impl MatrixSpec {
    /// Materialize the matrix.
    pub fn build(&self) -> Coo {
        let mut rng = Rng::new(self.seed);
        match self.family {
            // Quadrant weights chosen so max-degree/nnz matches real SNAP
            // graphs (~0.1-0.5%): Graph500's (0.57, 0.19, 0.19) produces a
            // far heavier head at these scales.
            Family::SnapRmat => gen::rmat(self.m, self.nnz, 0.45, 0.20, 0.20, &mut rng),
            Family::SsBanded => {
                let row_nnz = (self.nnz / self.m).max(1);
                let band = (row_nnz * 2).max(2);
                gen::banded(self.m, band, row_nnz, &mut rng)
            }
            Family::SsCircuit => {
                let off = (self.nnz / self.m).saturating_sub(1);
                gen::diagonal_dominant(self.m, off, &mut rng)
            }
            Family::SsUniform => gen::random_with_nnz(self.m, self.k, self.nnz, &mut rng),
            Family::SsBlock => {
                let bs = 16usize.min(self.m.max(1));
                let nblocks = (self.m / bs).max(1);
                let density =
                    (self.nnz as f64 / (nblocks as f64 * (bs * bs) as f64)).min(1.0);
                gen::block_diag(nblocks, bs, density, &mut rng)
            }
            // s = 0.8 keeps the Zipf head at a few percent of nnz, matching
            // SuiteSparse's recommender/optimization matrices.
            Family::SsPowerRows => gen::power_law_rows(self.m, self.k, self.nnz, 0.8, &mut rng),
        }
    }
}

/// Catalog scale: `Ci` caps per-matrix nnz for fast sweeps, `Full` includes
/// the multi-million-nnz tail of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// nnz capped at ~400k per matrix: full 1,400-SpMM sweep in ~a minute.
    Ci,
    /// nnz up to ~2×10⁷ (headline runs; minutes).
    Full,
}

/// The N values of the sweep (paper Table 2: N = 8..512).
pub const N_VALUES: [usize; 7] = [8, 16, 32, 64, 128, 256, 512];

/// Log-interpolate x in [0,1] between lo and hi.
fn logspace(lo: f64, hi: f64, x: f64) -> f64 {
    (lo.ln() + (hi.ln() - lo.ln()) * x).exp()
}

/// Build the 200-matrix catalog (50 SNAP-like + 150 SuiteSparse-like).
pub fn catalog(scale: Scale) -> Vec<MatrixSpec> {
    let (max_nnz, max_n) = match scale {
        Scale::Ci => (400_000usize, 120_000usize),
        Scale::Full => (20_000_000usize, 513_351usize),
    };
    let mut specs = Vec::with_capacity(200);

    // --- 50 SNAP-like graphs: n from ~1,005 to ~456,626, avg degree 4-40.
    for i in 0..50 {
        let x = i as f64 / 49.0;
        let n = logspace(1_005.0, (456_626.0f64).min(max_n as f64), x).round() as usize;
        let degree = 4.0 + 36.0 * ((i * 7) % 50) as f64 / 50.0;
        let nnz = ((n as f64 * degree) as usize).clamp(32, max_nnz);
        specs.push(MatrixSpec {
            name: format!("snap_rmat_{i:02}"),
            family: Family::SnapRmat,
            m: n,
            k: n,
            nnz,
            seed: 0x5EAF_0000 + i as u64,
        });
    }

    // --- 150 SuiteSparse-like across 5 families (30 each) + edge cases.
    let families = [
        Family::SsBanded,
        Family::SsCircuit,
        Family::SsUniform,
        Family::SsBlock,
        Family::SsPowerRows,
    ];
    for (fi, fam) in families.iter().enumerate() {
        for i in 0..30 {
            let x = i as f64 / 29.0;
            let n = logspace(64.0, (300_000.0f64).min(max_n as f64), x).round() as usize;
            let per_row = match fam {
                Family::SsBanded => 8 + (i % 24),
                Family::SsCircuit => 2 + (i % 8),
                Family::SsUniform => 4 + (i % 16),
                Family::SsBlock => 8,
                Family::SsPowerRows => 6 + (i % 20),
                Family::SnapRmat => unreachable!(),
            };
            let nnz = (n * per_row).clamp(10, max_nnz);
            let k = if *fam == Family::SsPowerRows || *fam == Family::SsUniform {
                // rectangular cases
                (n as f64 * logspace(0.5, 2.0, ((i * 13) % 30) as f64 / 29.0)).round() as usize
            } else {
                n
            }
            .max(5);
            specs.push(MatrixSpec {
                name: format!("ss_{}_{i:02}", family_tag(*fam)),
                family: *fam,
                m: n,
                k,
                nnz,
                seed: 0x55AA_0000 + ((fi as u64) << 8) + i as u64,
            });
        }
    }

    // Replace the first few SuiteSparse entries with named edge cases so the
    // catalog spans Table 2's extremes exactly (5 rows, 10 nnz, density 0.4).
    specs[50] = MatrixSpec {
        name: "ss_edge_tiny".into(),
        family: Family::SsUniform,
        m: 5,
        k: 5,
        nnz: 10,
        seed: 0xED6E_0001,
    };
    specs[80] = MatrixSpec {
        name: "ss_edge_dense".into(),
        family: Family::SsUniform,
        m: 64,
        k: 64,
        nnz: (64.0 * 64.0 * 0.4) as usize,
        seed: 0xED6E_0002,
    };
    // crystm03 stand-in (Table 1 breakdown workload): FEM banded,
    // 24,696 x 24,696 with 583,770 nnz (~23.6 nnz/row).
    specs[51] = crystm03_like();

    assert_eq!(specs.len(), 200);
    specs
}

/// The Table 1 workload: a crystm03-shaped banded FEM matrix.
pub fn crystm03_like() -> MatrixSpec {
    MatrixSpec {
        name: "crystm03_like".into(),
        family: Family::SsBanded,
        m: 24_696,
        k: 24_696,
        nnz: 583_770,
        seed: 0xC45731,
    }
}

fn family_tag(f: Family) -> &'static str {
    match f {
        Family::SnapRmat => "rmat",
        Family::SsBanded => "banded",
        Family::SsCircuit => "circuit",
        Family::SsUniform => "uniform",
        Family::SsBlock => "block",
        Family::SsPowerRows => "powrows",
    }
}

/// Catalog-wide statistics (regenerates Table 2).
#[derive(Debug, Clone)]
pub struct CatalogStats {
    /// Total matrix count.
    pub matrices: usize,
    /// Total SpMM count (matrices × N values).
    pub spmms: usize,
    /// (min, max) of rows/cols.
    pub dim_range: (usize, usize),
    /// (min, max) of nnz targets.
    pub nnz_range: (usize, usize),
    /// (min, max) of density.
    pub density_range: (f64, f64),
}

/// Compute Table 2 statistics from specs (no materialization needed).
pub fn stats(specs: &[MatrixSpec]) -> CatalogStats {
    let mut dim_lo = usize::MAX;
    let mut dim_hi = 0;
    let mut nnz_lo = usize::MAX;
    let mut nnz_hi = 0;
    let mut d_lo = f64::MAX;
    let mut d_hi = 0f64;
    for s in specs {
        dim_lo = dim_lo.min(s.m).min(s.k);
        dim_hi = dim_hi.max(s.m).max(s.k);
        nnz_lo = nnz_lo.min(s.nnz);
        nnz_hi = nnz_hi.max(s.nnz);
        let d = s.nnz as f64 / (s.m as f64 * s.k as f64);
        d_lo = d_lo.min(d);
        d_hi = d_hi.max(d);
    }
    CatalogStats {
        matrices: specs.len(),
        spmms: specs.len() * N_VALUES.len(),
        dim_range: (dim_lo, dim_hi),
        nnz_range: (nnz_lo, nnz_hi),
        density_range: (d_lo, d_hi),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_200_specs_1400_spmms() {
        let c = catalog(Scale::Ci);
        let st = stats(&c);
        assert_eq!(st.matrices, 200);
        assert_eq!(st.spmms, 1400);
    }

    #[test]
    fn fifty_snap_150_suitesparse() {
        let c = catalog(Scale::Ci);
        let snap = c.iter().filter(|s| s.family.source() == "SNAP").count();
        assert_eq!(snap, 50);
        assert_eq!(c.len() - snap, 150);
    }

    #[test]
    fn names_are_unique() {
        let c = catalog(Scale::Full);
        let mut names: Vec<&str> = c.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 200);
    }

    #[test]
    fn table2_ranges_covered() {
        let c = catalog(Scale::Full);
        let st = stats(&c);
        assert_eq!(st.dim_range.0, 5);
        assert!(st.dim_range.1 >= 400_000, "{}", st.dim_range.1);
        assert_eq!(st.nnz_range.0, 10);
        assert!(st.nnz_range.1 >= 10_000_000);
        assert!(st.density_range.0 < 1e-4);
        assert!(st.density_range.1 >= 0.39);
    }

    #[test]
    fn specs_build_to_matching_shapes() {
        let c = catalog(Scale::Ci);
        // Spot-check a few small ones from each family.
        for s in c.iter().filter(|s| s.m <= 2000).take(12) {
            let m = s.build();
            assert_eq!(m.m, s.m, "{}", s.name);
            assert_eq!(m.k, s.k, "{}", s.name);
            assert!(m.nnz() > 0, "{}", s.name);
            // Generators may merge duplicates: allow slack on nnz.
            assert!(
                m.nnz() <= s.nnz + s.m,
                "{}: nnz {} vs target {}",
                s.name,
                m.nnz(),
                s.nnz
            );
        }
    }

    #[test]
    fn crystm03_like_matches_paper_dims() {
        let spec = crystm03_like();
        assert_eq!(spec.m, 24_696);
        assert_eq!(spec.nnz, 583_770);
        let m = spec.build();
        assert!(m.nnz() > 500_000);
    }

    #[test]
    fn catalog_is_deterministic() {
        let a = catalog(Scale::Ci);
        let b = catalog(Scale::Ci);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.seed, y.seed);
        }
        let ma = a[0].build();
        let mb = b[0].build();
        assert_eq!(ma, mb);
    }
}
