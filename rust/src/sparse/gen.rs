//! Synthetic sparse-matrix generators.
//!
//! The paper evaluates on 50 SNAP graphs + 150 SuiteSparse matrices
//! (Table 2: row/col 5–513,351, NNZ 10–37,464,962, density 5.97e-6–0.4).
//! Offline we synthesize matrices with matching *statistics* — what drives
//! Sextans (and GPU) performance is (M, K, NNZ, row-degree distribution,
//! column locality), all of which these generators control:
//!
//! * [`rmat`] — recursive-matrix power-law graphs (SNAP-like: social
//!   networks, web graphs). Heavy-tailed row degrees = the worst case for
//!   row-based parallelization (paper Fig. 1).
//! * [`banded`] — FEM/structural matrices (SuiteSparse mechanics, e.g.
//!   crystm03 of Table 1): narrow column windows, high locality.
//! * [`random_uniform`] — Erdős–Rényi fill, the balanced case.
//! * [`diagonal_dominant`] — circuit-like: strong diagonal + random fill.
//! * [`block_diag`] — multi-physics / supernodal block structure.
//! * [`power_law_rows`] — Zipf row degrees with uniform columns (bipartite
//!   recommender-style data).

use super::coo::Coo;
use super::rng::Rng;

/// Uniform (Erdős–Rényi) matrix with expected density `density`.
/// Exact nnz = round(m * k * density), sampled without replacement when the
/// matrix is small enough, by rejection otherwise.
pub fn random_uniform(m: usize, k: usize, density: f64, rng: &mut Rng) -> Coo {
    let total = (m as f64 * k as f64 * density).round() as usize;
    random_with_nnz(m, k, total.min(m * k), rng)
}

/// Uniform matrix with an exact non-zero count (duplicate-free).
pub fn random_with_nnz(m: usize, k: usize, nnz: usize, rng: &mut Rng) -> Coo {
    assert!(m > 0 && k > 0, "empty shape");
    let cells = (m as u64).saturating_mul(k as u64);
    let nnz = nnz.min(cells.min(usize::MAX as u64) as usize);
    let mut rows = Vec::with_capacity(nnz);
    let mut cols = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    if (nnz as u64) * 3 >= cells {
        // Dense-ish: reservoir-sample cell indices without replacement.
        let mut picked: Vec<u64> = (0..cells).collect();
        rng.shuffle(&mut picked);
        picked.truncate(nnz);
        for cell in picked {
            rows.push((cell / k as u64) as u32);
            cols.push((cell % k as u64) as u32);
            vals.push(nonzero_val(rng));
        }
    } else {
        // Sparse: rejection-sample distinct cells.
        let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
        while rows.len() < nnz {
            let cell = rng.below(cells);
            if seen.insert(cell) {
                rows.push((cell / k as u64) as u32);
                cols.push((cell % k as u64) as u32);
                vals.push(nonzero_val(rng));
            }
        }
    }
    Coo { m, k, rows, cols, vals }
}

/// R-MAT recursive power-law graph (Chakrabarti et al.), the standard
/// SNAP-like generator. `(a, b, c, d)` are the quadrant probabilities; the
/// Graph500 defaults (0.57, 0.19, 0.19, 0.05) give realistic skew.
/// Duplicates are merged, so the final nnz may be slightly below `nnz`.
pub fn rmat(n: usize, nnz: usize, a: f64, b: f64, c: f64, rng: &mut Rng) -> Coo {
    assert!(n > 0);
    let scale = (n as f64).log2().ceil() as u32;
    let side = 1usize << scale;
    // Vertex-id permutation (as in Graph500): raw R-MAT correlates hotness
    // with the *bit pattern* of the index — hot rows all share low bits,
    // which would alias pathologically with any `row mod P` partitioning.
    // Real graph datasets have arbitrary node ids; shuffling restores that.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    let mut rows = Vec::with_capacity(nnz);
    let mut cols = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    let mut attempts = 0usize;
    while rows.len() < nnz && attempts < nnz * 4 {
        attempts += 1;
        let (mut r, mut cl) = (0usize, 0usize);
        let mut half = side >> 1;
        while half > 0 {
            let p = rng.f64();
            if p < a {
                // top-left
            } else if p < a + b {
                cl += half;
            } else if p < a + b + c {
                r += half;
            } else {
                r += half;
                cl += half;
            }
            half >>= 1;
        }
        if r < n && cl < n {
            rows.push(perm[r]);
            cols.push(perm[cl]);
            vals.push(nonzero_val(rng));
        }
    }
    let mut coo = Coo { m: n, k: n, rows, cols, vals };
    coo.sum_duplicates();
    coo
}

/// Banded matrix: each row has ~`row_nnz` entries within `|i - j| <= band`.
/// Models FEM/structural SuiteSparse matrices (crystm03 et al.).
pub fn banded(n: usize, band: usize, row_nnz: usize, rng: &mut Rng) -> Coo {
    assert!(n > 0);
    let band = band.max(1).min(n - 1).max(1);
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for i in 0..n {
        let lo = i.saturating_sub(band);
        let hi = (i + band + 1).min(n);
        let width = hi - lo;
        let want = row_nnz.min(width);
        // Sample `want` distinct offsets in [lo, hi).
        let mut offs: Vec<usize> = (lo..hi).collect();
        rng.shuffle(&mut offs);
        offs.truncate(want);
        offs.sort_unstable();
        for j in offs {
            rows.push(i as u32);
            cols.push(j as u32);
            vals.push(nonzero_val(rng));
        }
    }
    Coo { m: n, k: n, rows, cols, vals }
}

/// Circuit-like: full diagonal plus `offdiag_per_row` random off-diagonals.
pub fn diagonal_dominant(n: usize, offdiag_per_row: usize, rng: &mut Rng) -> Coo {
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for i in 0..n {
        rows.push(i as u32);
        cols.push(i as u32);
        vals.push(rng.range_f32(1.0, 4.0)); // dominant diagonal
        for _ in 0..offdiag_per_row {
            let j = rng.index(n);
            if j != i {
                rows.push(i as u32);
                cols.push(j as u32);
                vals.push(nonzero_val(rng) * 0.1);
            }
        }
    }
    let mut coo = Coo { m: n, k: n, rows, cols, vals };
    coo.sum_duplicates();
    coo
}

/// Block-diagonal with `nblocks` dense-ish blocks of size `bs` and density
/// `block_density` inside each block.
pub fn block_diag(nblocks: usize, bs: usize, block_density: f64, rng: &mut Rng) -> Coo {
    let n = nblocks * bs;
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for blk in 0..nblocks {
        let base = blk * bs;
        for i in 0..bs {
            for j in 0..bs {
                if rng.chance(block_density) {
                    rows.push((base + i) as u32);
                    cols.push((base + j) as u32);
                    vals.push(nonzero_val(rng));
                }
            }
        }
    }
    Coo { m: n, k: n, rows, cols, vals }
}

/// Zipf row degrees (exponent `s`), uniform column targets. `m` rows and
/// `k` columns may differ (bipartite data).
pub fn power_law_rows(m: usize, k: usize, nnz: usize, s: f64, rng: &mut Rng) -> Coo {
    assert!(m > 0 && k > 0);
    // Degree weights w_i = (i+1)^-s over a random row permutation.
    let mut weights: Vec<f64> = (0..m).map(|i| ((i + 1) as f64).powf(-s)).collect();
    let mut perm: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut perm);
    let total: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= total;
    }
    let mut rows = Vec::with_capacity(nnz);
    let mut cols = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    // Cumulative degree targets, then fill row by row to hit exact nnz.
    // Cap any single row at 0.5% of nnz (min 16): matches real datasets,
    // where even recommender heads stay well below a percent of all
    // interactions (an uncapped Zipf head at small m is a degenerate case).
    let cap = (nnz / 200).max(16);
    let mut remaining = nnz;
    for (i, &w) in weights.iter().enumerate() {
        let want = if i + 1 == m {
            remaining.min(cap)
        } else {
            ((nnz as f64 * w).round() as usize).min(remaining).min(cap)
        };
        let r = perm[i] as u32;
        for _ in 0..want {
            rows.push(r);
            cols.push(rng.index(k) as u32);
            vals.push(nonzero_val(rng));
        }
        remaining -= want;
        if remaining == 0 {
            break;
        }
    }
    let mut coo = Coo { m, k, rows, cols, vals };
    coo.sum_duplicates();
    coo
}

/// Identity-like diagonal matrix (edge case exerciser).
pub fn diagonal(n: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::empty(n, n);
    for i in 0..n {
        coo.rows.push(i as u32);
        coo.cols.push(i as u32);
        coo.vals.push(nonzero_val(rng));
    }
    coo
}

#[inline]
fn nonzero_val(rng: &mut Rng) -> f32 {
    // Normal values, re-drawn away from exact zero so pruning never fires.
    loop {
        let v = rng.normal();
        if v != 0.0 {
            return v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn random_uniform_hits_target_nnz() {
        let mut rng = Rng::new(1);
        let a = random_with_nnz(50, 60, 300, &mut rng);
        assert_eq!(a.nnz(), 300);
        assert_eq!((a.m, a.k), (50, 60));
    }

    #[test]
    fn random_uniform_no_duplicates() {
        let mut rng = Rng::new(2);
        let a = random_with_nnz(30, 30, 400, &mut rng);
        let mut cells: Vec<(u32, u32)> =
            a.rows.iter().zip(a.cols.iter()).map(|(&r, &c)| (r, c)).collect();
        cells.sort_unstable();
        cells.dedup();
        assert_eq!(cells.len(), a.nnz());
    }

    #[test]
    fn random_saturates_at_full() {
        let mut rng = Rng::new(3);
        let a = random_with_nnz(4, 4, 100, &mut rng);
        assert_eq!(a.nnz(), 16);
    }

    #[test]
    fn rmat_is_skewed() {
        let mut rng = Rng::new(4);
        let a = rmat(1024, 8192, 0.57, 0.19, 0.19, &mut rng);
        assert!(a.nnz() > 4000, "nnz {}", a.nnz());
        let counts = a.row_counts();
        let mean = a.nnz() as f64 / 1024.0;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max > 4.0 * mean, "rmat should be heavy-tailed: max {max} mean {mean}");
    }

    #[test]
    fn banded_respects_band() {
        let mut rng = Rng::new(5);
        let a = banded(100, 5, 4, &mut rng);
        for i in 0..a.nnz() {
            let d = (a.rows[i] as i64 - a.cols[i] as i64).abs();
            assert!(d <= 5, "entry outside band: {d}");
        }
        assert_eq!(a.nnz(), 400);
    }

    #[test]
    fn diagonal_dominant_has_full_diagonal() {
        let mut rng = Rng::new(6);
        let a = diagonal_dominant(64, 3, &mut rng);
        let mut diag = vec![false; 64];
        for i in 0..a.nnz() {
            if a.rows[i] == a.cols[i] {
                diag[a.rows[i] as usize] = true;
            }
        }
        assert!(diag.iter().all(|&d| d));
    }

    #[test]
    fn block_diag_stays_in_blocks() {
        let mut rng = Rng::new(7);
        let a = block_diag(4, 8, 0.5, &mut rng);
        for i in 0..a.nnz() {
            assert_eq!(a.rows[i] as usize / 8, a.cols[i] as usize / 8);
        }
    }

    #[test]
    fn power_law_nnz_and_skew() {
        let mut rng = Rng::new(8);
        let a = power_law_rows(5000, 4000, 50_000, 1.1, &mut rng);
        // Zipf heads collide and the per-row cap truncates; allow slack.
        assert!(a.nnz() <= 50_000 && a.nnz() > 25_000, "nnz {}", a.nnz());
        let max = a.max_row_nnz() as f64;
        let mean = a.nnz() as f64 / 5000.0;
        assert!(max > 5.0 * mean, "power-law should be skewed: {max} vs {mean}");
        // ...but the head must stay capped (0.5% of target nnz).
        assert!(a.max_row_nnz() <= (50_000 / 200).max(16));
    }

    #[test]
    fn generators_are_deterministic() {
        let a = rmat(256, 1000, 0.57, 0.19, 0.19, &mut Rng::new(99));
        let b = rmat(256, 1000, 0.57, 0.19, 0.19, &mut Rng::new(99));
        assert_eq!(a, b);
    }

    #[test]
    fn all_generators_in_bounds_property() {
        prop::check("gen_bounds", 0x6E4, 24, |rng| {
            let m = 2 + rng.index(128);
            let k = 2 + rng.index(128);
            let which = rng.index(6);
            let a = match which {
                0 => random_uniform(m, k, 0.05 + rng.f64() * 0.2, rng),
                1 => rmat(m.max(4), m * 4, 0.57, 0.19, 0.19, rng),
                2 => banded(m, 1 + rng.index(8), 1 + rng.index(6), rng),
                3 => diagonal_dominant(m, rng.index(4), rng),
                4 => block_diag(1 + m / 16, 8, 0.3, rng),
                _ => power_law_rows(m, k, m * 3, 1.0 + rng.f64(), rng),
            };
            for i in 0..a.nnz() {
                if a.rows[i] as usize >= a.m || a.cols[i] as usize >= a.k {
                    return Err(format!("gen {which}: entry oob"));
                }
                if a.vals[i] == 0.0 {
                    return Err(format!("gen {which}: explicit zero"));
                }
            }
            Ok(())
        });
    }
}
