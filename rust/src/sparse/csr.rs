//! Compressed Sparse Row (CSR) — the format GPUs (cuSPARSE `csrmm`) consume
//! and the input format of Sextans preprocessing's row-major baseline.

use anyhow::{bail, Result};

use super::coo::Coo;

/// CSR sparse matrix. `indptr.len() == m + 1`; row `r`'s entries live at
/// `indices[indptr[r]..indptr[r+1]]` (column indices, ascending within a
/// row after [`Csr::from_coo`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// Number of rows (M).
    pub m: usize,
    /// Number of columns (K).
    pub k: usize,
    /// Row pointers, length m + 1.
    pub indptr: Vec<usize>,
    /// Column index per non-zero.
    pub indices: Vec<u32>,
    /// Value per non-zero.
    pub vals: Vec<f32>,
}

impl Csr {
    /// Build from raw parts, validating the invariants.
    pub fn new(
        m: usize,
        k: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        vals: Vec<f32>,
    ) -> Result<Self> {
        if indptr.len() != m + 1 {
            bail!("indptr length {} != m+1 = {}", indptr.len(), m + 1);
        }
        if indptr[0] != 0 || *indptr.last().unwrap() != indices.len() {
            bail!("indptr endpoints invalid");
        }
        if indptr.windows(2).any(|w| w[0] > w[1]) {
            bail!("indptr not monotone");
        }
        if indices.len() != vals.len() {
            bail!("indices/vals length mismatch");
        }
        if indices.iter().any(|&c| c as usize >= k) {
            bail!("column index out of bounds for k={k}");
        }
        Ok(Csr { m, k, indptr, indices, vals })
    }

    /// Convert from COO (O(nnz), counting sort by row; columns sorted within
    /// each row; duplicates preserved).
    pub fn from_coo(coo: &Coo) -> Csr {
        let nnz = coo.nnz();
        let mut indptr = vec![0usize; coo.m + 1];
        for &r in &coo.rows {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..coo.m {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; nnz];
        let mut vals = vec![0f32; nnz];
        let mut cursor = indptr.clone();
        for i in 0..nnz {
            let r = coo.rows[i] as usize;
            indices[cursor[r]] = coo.cols[i];
            vals[cursor[r]] = coo.vals[i];
            cursor[r] += 1;
        }
        // Sort columns within each row (insertion-friendly sizes expected).
        let mut csr = Csr { m: coo.m, k: coo.k, indptr, indices, vals };
        for r in 0..csr.m {
            let (s, e) = (csr.indptr[r], csr.indptr[r + 1]);
            let mut pairs: Vec<(u32, f32)> = csr.indices[s..e]
                .iter()
                .copied()
                .zip(csr.vals[s..e].iter().copied())
                .collect();
            pairs.sort_by_key(|p| p.0);
            for (j, (c, v)) in pairs.into_iter().enumerate() {
                csr.indices[s + j] = c;
                csr.vals[s + j] = v;
            }
        }
        csr
    }

    /// Convert back to row-major-sorted COO.
    pub fn to_coo(&self) -> Coo {
        let nnz = self.vals.len();
        let mut rows = Vec::with_capacity(nnz);
        for r in 0..self.m {
            for _ in self.indptr[r]..self.indptr[r + 1] {
                rows.push(r as u32);
            }
        }
        Coo {
            m: self.m,
            k: self.k,
            rows,
            cols: self.indices.clone(),
            vals: self.vals.clone(),
        }
    }

    /// Number of stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Non-zeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// `C = alpha * A @ B + beta * C` with row-major dense B (k x n), C (m x n).
    /// This is the cuSPARSE-csrmm-shaped reference used by the GPU model's
    /// functional check.
    pub fn spmm_reference(&self, b: &[f32], c: &mut [f32], n: usize, alpha: f32, beta: f32) {
        assert_eq!(b.len(), self.k * n);
        assert_eq!(c.len(), self.m * n);
        for r in 0..self.m {
            let mut acc = vec![0f32; n];
            for idx in self.indptr[r]..self.indptr[r + 1] {
                let col = self.indices[idx] as usize;
                let v = self.vals[idx];
                let brow = &b[col * n..col * n + n];
                for q in 0..n {
                    acc[q] += v * brow[q];
                }
            }
            let crow = &mut c[r * n..r * n + n];
            for q in 0..n {
                crow[q] = alpha * acc[q] + beta * crow[q];
            }
        }
    }

    /// CSR memory footprint in bytes (paper §4.2.3: 8 B/nnz + 4 B/row-ptr).
    pub fn footprint_bytes(&self) -> usize {
        self.nnz() * 8 + (self.m + 1) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sparse::gen;

    fn small_coo() -> Coo {
        Coo::new(3, 3, vec![2, 0, 0], vec![1, 2, 0], vec![5.0, 2.0, 1.0]).unwrap()
    }

    #[test]
    fn from_coo_sorts_rows_and_cols() {
        let csr = Csr::from_coo(&small_coo());
        assert_eq!(csr.indptr, vec![0, 2, 2, 3]);
        assert_eq!(csr.indices, vec![0, 2, 1]);
        assert_eq!(csr.vals, vec![1.0, 2.0, 5.0]);
    }

    #[test]
    fn new_validates() {
        assert!(Csr::new(1, 1, vec![0], vec![], vec![]).is_err()); // short indptr
        assert!(Csr::new(1, 1, vec![0, 2], vec![0], vec![1.0]).is_err()); // endpoint
        assert!(Csr::new(1, 1, vec![0, 1], vec![1], vec![1.0]).is_err()); // col oob
    }

    #[test]
    fn roundtrip_coo_csr_coo() {
        let mut a = small_coo();
        a.sort_row_major();
        let rt = Csr::from_coo(&a).to_coo();
        assert_eq!(a, rt);
    }

    #[test]
    fn csr_and_coo_spmm_agree_property() {
        prop::check("csr_coo_spmm_agree", 0xC5A, 32, |rng| {
            let m = 1 + rng.index(40);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(8);
            let a = gen::random_uniform(m, k, 0.2, rng);
            let csr = Csr::from_coo(&a);
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let (mut c1, mut c2) = (c0.clone(), c0);
            a.spmm_reference(&b, &mut c1, n, 1.5, -0.5);
            csr.spmm_reference(&b, &mut c2, n, 1.5, -0.5);
            prop::assert_allclose(&c1, &c2, 1e-5, 1e-5)
        });
    }

    #[test]
    fn row_nnz_counts() {
        let csr = Csr::from_coo(&small_coo());
        assert_eq!(csr.row_nnz(0), 2);
        assert_eq!(csr.row_nnz(1), 0);
        assert_eq!(csr.row_nnz(2), 1);
    }
}
