//! Sparse-matrix substrate: formats, I/O, generators, and the evaluation
//! catalog (paper §2.1, §4.1, Table 2).

pub mod catalog;
pub mod coo;
pub mod csr;
pub mod gen;
pub mod mm_io;
pub mod rng;

pub use coo::Coo;
pub use csr::Csr;
