//! Deterministic PRNG for matrix generation and property tests.
//!
//! The offline environment has no `rand` crate, so we ship a small, fast,
//! well-understood generator: xoshiro256** (Blackman & Vigna) seeded through
//! SplitMix64. Determinism matters more than statistical perfection here —
//! every synthetic matrix in the catalog and every property-test case is
//! reproducible from a printed seed.

/// xoshiro256** PRNG. Not cryptographic; excellent for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed via SplitMix64 (avoids all-zero states and
    /// correlated low-entropy seeds).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Uniform value in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG (for parallel deterministic streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(11);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            buckets[r.index(10)] += 1;
        }
        for &b in &buckets {
            assert!((800..1200).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn normal_moments_rough() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let (mut sum, mut sum2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
