//! Evaluation metrics (paper §4.2): throughput, memory-bandwidth
//! utilization, energy efficiency, geomean and CDF summaries.

use crate::perfmodel::Platform;

/// Geometric mean. Ignores non-positive entries (they would be undefined);
/// returns 0.0 for an empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|&&x| x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        return 0.0;
    }
    (logs.iter().sum::<f64>() / logs.len() as f64).exp()
}

/// Empirical CDF: sorted (value, fraction ≤ value) pairs (Fig. 8b).
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    sorted
        .into_iter()
        .enumerate()
        .map(|(i, v)| (v, (i + 1) as f64 / n))
        .collect()
}

/// Running max over problem size (Fig. 8a "peak throughput" transform):
/// input (size, value) pairs, output sorted by size with cumulative max.
pub fn running_peak(points: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<(f64, f64)> = points.to_vec();
    sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut peak = f64::MIN;
    sorted
        .into_iter()
        .map(|(s, v)| {
            peak = peak.max(v);
            (s, peak)
        })
        .collect()
}

/// Memory-bandwidth utilization, paper §4.2.3 (Fig. 9):
/// `4·(NNZ + N·(2M + K)) / t / Bdw` — the *algorithmic* bytes over the
/// platform's max bandwidth. Explicitly NOT an occupancy rate: an
/// inefficient design can occupy 100% of its bandwidth doing nothing.
pub fn bandwidth_utilization(
    nnz: usize,
    m: usize,
    k: usize,
    n: usize,
    seconds: f64,
    bandwidth_gbps: f64,
) -> f64 {
    let bytes = 4.0 * (nnz as f64 + n as f64 * (2.0 * m as f64 + k as f64));
    bytes / seconds / (bandwidth_gbps * 1e9)
}

/// One sweep data point: a (matrix, N, platform) cell of the 1,400-SpMM
/// evaluation.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Matrix name (catalog id).
    pub matrix: String,
    /// Platform.
    pub platform: Platform,
    /// B/C column count.
    pub n: usize,
    /// Problem size in FLOP (Fig. 7 X-axis).
    pub flops: u64,
    /// Execution time, seconds (Fig. 7b Y-axis).
    pub seconds: f64,
    /// Throughput GFLOP/s (Fig. 7a Y-axis).
    pub gflops: f64,
    /// Bandwidth utilization in [0, 1] (Fig. 9).
    pub bw_util: f64,
    /// Energy efficiency FLOP/J (Fig. 10).
    pub flop_per_joule: f64,
}

/// Per-platform summary over a sweep (drives the headline numbers).
#[derive(Clone, Debug)]
pub struct PlatformSummary {
    /// Platform.
    pub platform: Platform,
    /// Geomean throughput (GFLOP/s).
    pub geomean_gflops: f64,
    /// Max achieved throughput (Table 3 "Peak Th.").
    pub peak_gflops: f64,
    /// Geomean bandwidth utilization.
    pub geomean_bw_util: f64,
    /// Max bandwidth utilization.
    pub max_bw_util: f64,
    /// Geomean energy efficiency (FLOP/J).
    pub geomean_flop_per_joule: f64,
    /// Max energy efficiency (FLOP/J).
    pub max_flop_per_joule: f64,
}

/// Summarize one platform's points.
pub fn summarize(platform: Platform, points: &[SweepPoint]) -> PlatformSummary {
    let sel: Vec<&SweepPoint> = points.iter().filter(|p| p.platform == platform).collect();
    let gf: Vec<f64> = sel.iter().map(|p| p.gflops).collect();
    let bw: Vec<f64> = sel.iter().map(|p| p.bw_util).collect();
    let ej: Vec<f64> = sel.iter().map(|p| p.flop_per_joule).collect();
    PlatformSummary {
        platform,
        geomean_gflops: geomean(&gf),
        peak_gflops: gf.iter().cloned().fold(0.0, f64::max),
        geomean_bw_util: geomean(&bw),
        max_bw_util: bw.iter().cloned().fold(0.0, f64::max),
        geomean_flop_per_joule: geomean(&ej),
        max_flop_per_joule: ej.iter().cloned().fold(0.0, f64::max),
    }
}

/// Geomean speedup of `platform` over `baseline` on matched (matrix, N)
/// cells — the paper's headline statistic ("2.50x geomean over K80").
pub fn geomean_speedup(points: &[SweepPoint], platform: Platform, baseline: Platform) -> f64 {
    use std::collections::HashMap;
    let mut base: HashMap<(&str, usize), f64> = HashMap::new();
    for p in points.iter().filter(|p| p.platform == baseline) {
        base.insert((p.matrix.as_str(), p.n), p.seconds);
    }
    let ratios: Vec<f64> = points
        .iter()
        .filter(|p| p.platform == platform)
        .filter_map(|p| base.get(&(p.matrix.as_str(), p.n)).map(|tb| tb / p.seconds))
        .collect();
    geomean(&ratios)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        // Non-positive values are skipped, not poison.
        assert!((geomean(&[0.0, 4.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_is_monotone_and_ends_at_one() {
        let c = cdf(&[3.0, 1.0, 2.0, 2.0]);
        assert_eq!(c.len(), 4);
        assert_eq!(c.last().unwrap().1, 1.0);
        for w in c.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn running_peak_is_cumulative_max() {
        let p = running_peak(&[(1.0, 5.0), (3.0, 2.0), (2.0, 7.0)]);
        assert_eq!(p, vec![(1.0, 5.0), (2.0, 7.0), (3.0, 7.0)]);
    }

    #[test]
    fn bandwidth_utilization_formula() {
        // 4*(100 + 8*(2*10+20)) bytes in 1 s on 1 GB/s.
        let u = bandwidth_utilization(100, 10, 20, 8, 1.0, 1e-9 * 1.0);
        let bytes = 4.0 * (100.0 + 8.0 * 40.0);
        assert!((u - bytes).abs() < 1e-9);
    }

    #[test]
    fn speedup_uses_matched_cells() {
        let mk = |platform, matrix: &str, seconds| SweepPoint {
            matrix: matrix.into(),
            platform,
            n: 8,
            flops: 1,
            seconds,
            gflops: 1.0,
            bw_util: 0.1,
            flop_per_joule: 1.0,
        };
        let pts = vec![
            mk(Platform::K80, "a", 2.0),
            mk(Platform::Sextans, "a", 1.0),
            mk(Platform::K80, "b", 8.0),
            mk(Platform::Sextans, "b", 1.0),
        ];
        let s = geomean_speedup(&pts, Platform::Sextans, Platform::K80);
        assert!((s - 4.0).abs() < 1e-12); // geomean(2, 8) = 4
    }

    #[test]
    fn summarize_splits_platforms() {
        let mk = |platform, gflops| SweepPoint {
            matrix: "m".into(),
            platform,
            n: 8,
            flops: 1,
            seconds: 1.0,
            gflops,
            bw_util: 0.02,
            flop_per_joule: 1e8,
        };
        let pts = vec![mk(Platform::K80, 10.0), mk(Platform::V100, 100.0)];
        let k80 = summarize(Platform::K80, &pts);
        assert_eq!(k80.peak_gflops, 10.0);
        let v100 = summarize(Platform::V100, &pts);
        assert_eq!(v100.peak_gflops, 100.0);
    }
}
