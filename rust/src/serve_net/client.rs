//! Client library for the network front door.
//!
//! [`FrontClient`] wraps one connection and speaks the framed protocol:
//! chunked image registration, chunked submit, streamed result fetch.
//! Typed sheds ([`Op::Shed`] frames) surface as [`ClientError::Shed`]
//! with the server's [`ShedReason`] intact — callers (the load generator
//! above all) can count queue-full vs image-quota vs draining sheds
//! without string matching.

use std::fmt;
use std::net::TcpStream;
use std::time::Duration;

use super::proto::{self, AwaitOk, FrontStatus, ImageInfo, ShedReason};
use crate::net::wire::{self, Op, WireError};
use crate::sched::ScheduledMatrix;

/// Everything a front-door call can fail with.
#[derive(Debug)]
pub enum ClientError {
    /// Transport or codec failure.
    Wire(WireError),
    /// The server shed the request — load, not failure.
    Shed {
        /// Why the server refused.
        reason: ShedReason,
        /// Human-readable detail from the server.
        message: String,
    },
    /// The server replied with an error frame (bad request, unknown
    /// ticket, pipeline failure, ...).
    Server(String),
    /// A retry loop gave up: every attempt failed and the budget ran
    /// out. Carries the retry telemetry the final attempt alone cannot —
    /// how many attempts ran, how long the loop slept between them, and
    /// the last address that failed (when known).
    RetriesExhausted {
        /// Total attempts made (the first try plus every retry).
        attempts: u32,
        /// Total backoff slept across all retries.
        total_backoff: std::time::Duration,
        /// Address of the last failing attempt, when the caller retried
        /// against a known endpoint.
        last_addr: Option<String>,
        /// The final attempt's error.
        last: Box<ClientError>,
    },
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Shed { reason, message } => write!(f, "shed ({reason}): {message}"),
            ClientError::Server(msg) => write!(f, "server: {msg}"),
            ClientError::RetriesExhausted { attempts, total_backoff, last_addr, last } => {
                write!(
                    f,
                    "retries exhausted after {attempts} attempts ({:.0} ms backoff",
                    total_backoff.as_secs_f64() * 1e3,
                )?;
                if let Some(addr) = last_addr {
                    write!(f, ", last addr {addr}")?;
                }
                write!(f, "): {last}")
            }
        }
    }
}

impl ClientError {
    /// The terminal failure for classification: a
    /// [`ClientError::RetriesExhausted`] unwraps to its final attempt's
    /// error, everything else is itself.
    pub fn terminal(&self) -> &ClientError {
        match self {
            ClientError::RetriesExhausted { last, .. } => last.terminal(),
            other => other,
        }
    }
}

impl std::error::Error for ClientError {}

impl From<WireError> for ClientError {
    fn from(e: WireError) -> ClientError {
        ClientError::Wire(e)
    }
}

/// One completed request as the client sees it: the C panel plus the
/// per-stage timing the server measured.
#[derive(Debug)]
pub struct FrontResponse {
    /// C_out, row-major M × n (zero-filled when `error` is set).
    pub c: Vec<f32>,
    /// Server-side per-stage timing and attribution.
    pub timing: AwaitOk,
}

/// One connection to a front door.
pub struct FrontClient {
    stream: TcpStream,
}

impl FrontClient {
    /// Connect with a connect/read/write timeout.
    pub fn connect(addr: &str, timeout: Duration) -> Result<FrontClient, ClientError> {
        let sock_addr = addr
            .parse()
            .map_err(|_| ClientError::Server(format!("bad address {addr}")))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .map_err(|e| ClientError::Wire(WireError::from(e)))?;
        let _ = stream.set_read_timeout(Some(timeout));
        let _ = stream.set_write_timeout(Some(timeout));
        let _ = stream.set_nodelay(true);
        Ok(FrontClient { stream })
    }

    /// One request, one reply frame; Err and Shed frames become typed
    /// errors.
    fn rpc(&mut self, op: Op, payload: &[u8]) -> Result<Vec<u8>, ClientError> {
        wire::write_frame(&mut self.stream, op, payload)?;
        self.read_reply()
    }

    fn read_reply(&mut self) -> Result<Vec<u8>, ClientError> {
        let (op, payload) = wire::read_frame(&mut self.stream)?;
        match op {
            Op::Ok => Ok(payload),
            Op::Err => Err(ClientError::Server(
                String::from_utf8_lossy(&payload).into_owned(),
            )),
            Op::Shed => {
                let (reason, message) = proto::decode_shed(&payload)?;
                Err(ClientError::Shed { reason, message })
            }
            other => Err(ClientError::Wire(WireError::Malformed(format!(
                "unexpected {other:?} reply"
            )))),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.rpc(Op::Ping, &[]).map(|_| ())
    }

    /// Front-door status (served spec, drain flag, counters).
    pub fn status(&mut self) -> Result<FrontStatus, ClientError> {
        let payload = self.rpc(Op::FrontStatus, &[])?;
        Ok(proto::decode_status_ok(&payload)?)
    }

    /// The coordinator's live metrics summary as pretty JSON.
    pub fn metrics_json(&mut self) -> Result<String, ClientError> {
        let payload = self.rpc(Op::Metrics, &[])?;
        Ok(String::from_utf8_lossy(&payload).into_owned())
    }

    /// Register a scheduled image, streaming the encoded bytes in
    /// `chunk_bytes`-sized pieces so arbitrarily large images never need
    /// one giant frame.
    pub fn register_image(
        &mut self,
        matrix: &ScheduledMatrix,
        chunk_bytes: usize,
    ) -> Result<ImageInfo, ClientError> {
        let bytes = wire::encode_image(matrix);
        let payload = self.rpc(Op::RegisterBegin, &proto::encode_register_begin(bytes.len() as u64))?;
        let token = proto::decode_u64(&payload)?;
        let step = chunk_bytes.max(1);
        let mut offset = 0usize;
        while offset < bytes.len() {
            let end = (offset + step).min(bytes.len());
            self.rpc(
                Op::RegisterChunk,
                &proto::encode_register_chunk(token, offset as u64, &bytes[offset..end]),
            )?;
            offset = end;
        }
        let payload = self.rpc(Op::RegisterEnd, &proto::encode_u64(token))?;
        Ok(proto::decode_register_ok(&payload)?)
    }

    /// Open a submit and stream the B and C panels in column blocks of
    /// `col_block` columns (0 = one block). Returns the server ticket;
    /// the pipeline is running by the time this returns. A shed comes
    /// back as [`ClientError::Shed`].
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &mut self,
        image: &ImageInfo,
        n: usize,
        alpha: f32,
        beta: f32,
        b: &[f32],
        c: &[f32],
        col_block: usize,
    ) -> Result<u64, ClientError> {
        self.submit_deadline(image, n, alpha, beta, b, c, col_block, 0)
    }

    /// [`FrontClient::submit`] with a deadline budget in milliseconds
    /// (`0` = no deadline). The server stamps an absolute deadline when
    /// the Submit frame arrives; a request still queued when it expires
    /// comes back as [`ClientError::Shed`] with
    /// [`ShedReason::DeadlineExceeded`] (at admission) or as a pipeline
    /// error naming the stage that shed it.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_deadline(
        &mut self,
        image: &ImageInfo,
        n: usize,
        alpha: f32,
        beta: f32,
        b: &[f32],
        c: &[f32],
        col_block: usize,
        deadline_ms: u64,
    ) -> Result<u64, ClientError> {
        let (m, k) = (image.m as usize, image.k as usize);
        assert_eq!(b.len(), k * n, "B must be row-major K x n");
        assert_eq!(c.len(), m * n, "C must be row-major M x n");
        let payload = self.rpc(
            Op::Submit,
            &proto::encode_submit(image.id, n, alpha, beta, deadline_ms),
        )?;
        let ticket = proto::decode_u64(&payload)?;
        let step = if col_block == 0 { n } else { col_block.min(n) };
        let mut col0 = 0usize;
        while col0 < n {
            let ncols = step.min(n - col0);
            let b_block = gather(b, n, col0, ncols);
            let c_block = gather(c, n, col0, ncols);
            self.rpc(
                Op::SubmitChunk,
                &proto::encode_submit_chunk(ticket, col0 as u64, ncols as u64, &b_block, &c_block),
            )?;
            col0 += ncols;
        }
        self.rpc(Op::SubmitEnd, &proto::encode_u64(ticket))?;
        Ok(ticket)
    }

    /// Non-blocking readiness check for a ticket.
    pub fn poll(&mut self, ticket: u64) -> Result<bool, ClientError> {
        let payload = self.rpc(Op::Poll, &proto::encode_u64(ticket))?;
        match payload.as_slice() {
            [done] => Ok(*done != 0),
            _ => Err(ClientError::Wire(WireError::Malformed(
                "poll reply is not one byte".into(),
            ))),
        }
    }

    /// Await a ticket and collect the streamed C panel (`m × n`,
    /// streamed back in `chunk_cols`-column blocks; 0 = one block).
    pub fn fetch(
        &mut self,
        ticket: u64,
        m: usize,
        n: usize,
        chunk_cols: usize,
    ) -> Result<FrontResponse, ClientError> {
        wire::write_frame(
            &mut self.stream,
            Op::Await,
            &proto::encode_await(ticket, chunk_cols as u64),
        )?;
        let mut c = vec![0.0f32; m * n];
        loop {
            let (op, payload) = wire::read_frame(&mut self.stream)?;
            match op {
                Op::Chunk => {
                    let (col0, ncols, block) = proto::decode_result_chunk(&payload)?;
                    let (col0, ncols) = (col0 as usize, ncols as usize);
                    if ncols == 0 || col0 + ncols > n || block.len() != m * ncols {
                        return Err(ClientError::Wire(WireError::Malformed(format!(
                            "result chunk [{col0}, {}) does not fit {m} x {n}",
                            col0 + ncols
                        ))));
                    }
                    for r in 0..m {
                        c[r * n + col0..r * n + col0 + ncols]
                            .copy_from_slice(&block[r * ncols..(r + 1) * ncols]);
                    }
                }
                Op::Ok => {
                    let timing = proto::decode_await_ok(&payload)?;
                    return Ok(FrontResponse { c, timing });
                }
                Op::Err => {
                    return Err(ClientError::Server(
                        String::from_utf8_lossy(&payload).into_owned(),
                    ))
                }
                Op::Shed => {
                    // Post-admission shed (deadline expiry in the batcher
                    // or at dispatch pickup) — typed, not a server error.
                    let (reason, message) = proto::decode_shed(&payload)?;
                    return Err(ClientError::Shed { reason, message });
                }
                other => {
                    return Err(ClientError::Wire(WireError::Malformed(format!(
                        "unexpected {other:?} during fetch"
                    ))))
                }
            }
        }
    }

    /// Submit + fetch in one call (blocking convenience).
    #[allow(clippy::too_many_arguments)]
    pub fn call(
        &mut self,
        image: &ImageInfo,
        n: usize,
        alpha: f32,
        beta: f32,
        b: &[f32],
        c: &[f32],
        col_block: usize,
    ) -> Result<FrontResponse, ClientError> {
        let ticket = self.submit(image, n, alpha, beta, b, c, col_block)?;
        self.fetch(ticket, image.m as usize, n, col_block)
    }

    /// [`FrontClient::call`] with a deadline budget in milliseconds
    /// (`0` = no deadline).
    #[allow(clippy::too_many_arguments)]
    pub fn call_deadline(
        &mut self,
        image: &ImageInfo,
        n: usize,
        alpha: f32,
        beta: f32,
        b: &[f32],
        c: &[f32],
        col_block: usize,
        deadline_ms: u64,
    ) -> Result<FrontResponse, ClientError> {
        let ticket =
            self.submit_deadline(image, n, alpha, beta, b, c, col_block, deadline_ms)?;
        self.fetch(ticket, image.m as usize, n, col_block)
    }

    /// Ask the server to stop accepting new work (in-flight finishes).
    pub fn drain(&mut self) -> Result<(), ClientError> {
        self.rpc(Op::Drain, &[]).map(|_| ())
    }

    /// Ask the server to shut down its accept loop and drain the
    /// pipeline.
    pub fn shutdown_server(&mut self) -> Result<(), ClientError> {
        self.rpc(Op::Shutdown, &[]).map(|_| ())
    }
}

/// Extract the `[col0, col0+ncols)` column block of a row-major
/// `rows × n` panel.
fn gather(panel: &[f32], n: usize, col0: usize, ncols: usize) -> Vec<f32> {
    let rows = panel.len() / n;
    let mut block = Vec::with_capacity(rows * ncols);
    for r in 0..rows {
        block.extend_from_slice(&panel[r * n + col0..r * n + col0 + ncols]);
    }
    block
}
