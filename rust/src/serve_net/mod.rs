//! Network front door for the serving pipeline.
//!
//! Where [`crate::net`] is the *back* of the deployment — workers holding
//! matrix shards, driven by the `remote:` backend — this module is the
//! *front*: the socket clients connect to. It reuses the same framed
//! binary protocol ([`crate::net::wire`]: `SXTN` magic, version gate,
//! length-prefixed frames) with a client-facing opcode set layered on
//! top:
//!
//! ```text
//!                         clients (FrontClient / sextans loadgen)
//!                               │ framed TCP, Op 10..20
//!                               ▼
//!  ┌───────────────────── serve_net::FrontDoor ─────────────────────┐
//!  │ accept gate (AdmissionGate)   thread per connection            │
//!  │ chunked register / submit     streamed result chunks           │
//!  │ net.frontend spans            typed Shed frames                │
//!  └──────────────────────────────┬─────────────────────────────────┘
//!                                 ▼
//!                    coordinator::Server (4-stage pipeline)
//! ```
//!
//! Three design rules, all load-bearing:
//!
//! 1. **Panels stream in column blocks.** B and C upload (and C_out
//!    downloads) move in `[col0, col0+ncols)` blocks, so transfer
//!    overlaps compute and no frame ever needs the full panel — the
//!    paper's streaming discipline applied to the serving edge.
//! 2. **Backpressure is typed, end to end.** Accept-gate overflow,
//!    pipeline admission sheds, per-image quota trips, and draining all
//!    come back as [`proto::ShedReason`]-tagged `Shed` frames; an
//!    overloaded front door sheds, it never queues unboundedly.
//! 3. **The network edge is in the trace.** Each submit opens a
//!    `net.frontend` span and parents the pipeline's `request` span
//!    under it via the thread-local span context, so one trace covers
//!    socket to executor.
//!
//! [`loadgen`] drives all of this open-loop for capacity measurement
//! (`sextans loadgen`), persisting `BENCH_serve_*.json` snapshots in the
//! same schema-v1 trajectory the kernel benches use. [`retry`] gives
//! clients a bounded, jittered-backoff retry policy that reconnects on
//! transport errors and — deliberately — never retries typed `Shed`
//! backpressure by default.

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod retry;
pub mod server;

pub use client::{ClientError, FrontClient, FrontResponse};
pub use loadgen::{LoadReport, LoadgenOptions, Mix};
pub use proto::{AwaitOk, FrontStatus, ImageInfo, ShedReason};
pub use retry::{call_with_retry, retry_loop, RetryPolicy};
pub use server::{FrontDoor, FrontDoorConfig};
