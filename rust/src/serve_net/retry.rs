//! Client-side retry/backoff policy for [`FrontClient`] calls.
//!
//! Transport can flake (connection refused during a rolling restart, a
//! dropped socket mid-frame); a *shed* cannot — it is the server's typed,
//! deliberate answer. The policy encodes that asymmetry:
//!
//! * [`ClientError::Wire`] (connect/transport/codec failures) is retried
//!   up to [`RetryPolicy::max_retries`] times with capped exponential
//!   backoff, **reconnecting first** — after a wire error the connection
//!   state is unknowable, so the old socket is discarded.
//! * [`ClientError::Shed`] is **not** retried by default: the admission
//!   gate already decided the deployment is saturated, and a hot retry
//!   loop is exactly the traffic it is shedding. Callers that want to
//!   wait out a drain can opt in via [`RetryPolicy::retry_sheds`] (the
//!   backoff still applies, so opted-in retries arrive decorrelated).
//! * [`ClientError::Server`] is never retried — the request itself is
//!   wrong, and resending it cannot help.
//!
//! Backoff is "decorrelated-half" jitter: retry `i` sleeps a duration
//! drawn deterministically (splitmix64 over [`RetryPolicy::seed`] and the
//! attempt index) from `[d/2, d]`, where `d = min(base · 2^i, max)`.
//! Determinism keeps the schedule unit-testable and reproducible in
//! traces while still decorrelating a fleet of clients with distinct
//! seeds.
//!
//! SpMM requests are pure reads of a registered image, so re-submitting
//! after a wire error is semantically safe — at worst the server computes
//! a panel twice.

use std::time::Duration;

use super::client::{ClientError, FrontClient, FrontResponse};
use super::proto::ImageInfo;

/// Bounded-retry policy with capped, jittered exponential backoff.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 disables retrying; the first
    /// attempt always runs).
    pub max_retries: u32,
    /// Delay scale for the first retry.
    pub base_delay: Duration,
    /// Ceiling on any single backoff delay.
    pub max_delay: Duration,
    /// Also retry typed [`ClientError::Shed`] responses. Off by default —
    /// sheds are deliberate backpressure, not failures.
    pub retry_sheds: bool,
    /// Jitter seed; give each client its own to decorrelate a fleet.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 3,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_secs(1),
            retry_sheds: false,
            seed: 0x5EC7_A115,
        }
    }
}

impl RetryPolicy {
    /// The backoff before retry `attempt` (0-based): deterministic jitter
    /// in `[d/2, d]` with `d = min(base · 2^attempt, max)`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let base = self.base_delay.as_nanos() as u64;
        let cap = self.max_delay.as_nanos() as u64;
        let exp = attempt.min(20); // 2^20 · base already dwarfs any sane cap
        let full = base.saturating_mul(1u64 << exp).min(cap).max(1);
        let half = full / 2;
        let r = splitmix64(self.seed ^ u64::from(attempt).wrapping_mul(0xA24B_AED4_963E_E407));
        Duration::from_nanos(half + r % (full - half + 1))
    }

    /// Whether `err` warrants retry number `attempt` (0-based).
    pub fn should_retry(&self, err: &ClientError, attempt: u32) -> bool {
        if attempt >= self.max_retries {
            return false;
        }
        match err {
            ClientError::Wire(_) => true,
            ClientError::Shed { .. } => self.retry_sheds,
            ClientError::Server(_) => false,
            // Never nest retry loops: an exhausted budget is final.
            ClientError::RetriesExhausted { .. } => false,
        }
    }
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Drive `op` against a connection under `policy`. `connect` builds (and
/// after a wire error, rebuilds) the connection; `sleep` performs the
/// backoff waits — injected so the schedule is unit-testable without
/// sockets or clocks. Generic over the connection type for the same
/// reason; production callers pass [`FrontClient`] closures (see
/// [`call_with_retry`]).
///
/// A failure on the very first attempt (nothing retried — a typed shed
/// under the default policy, a server error) returns that error raw.
/// Once at least one retry ran, giving up returns
/// [`ClientError::RetriesExhausted`] instead, carrying the attempt
/// count, the total backoff slept, and the final error — the telemetry a
/// caller needs to distinguish "down hard" from "flaked once".
pub fn retry_loop<Conn, T>(
    policy: &RetryPolicy,
    mut connect: impl FnMut() -> Result<Conn, ClientError>,
    mut op: impl FnMut(&mut Conn) -> Result<T, ClientError>,
    mut sleep: impl FnMut(Duration),
) -> Result<T, ClientError> {
    let mut conn: Option<Conn> = None;
    let mut attempt = 0u32;
    let mut total_backoff = Duration::ZERO;
    loop {
        let result = if let Some(c) = conn.as_mut() {
            op(c)
        } else {
            match connect() {
                Ok(c) => {
                    conn = Some(c);
                    op(conn.as_mut().expect("just connected"))
                }
                Err(e) => Err(e),
            }
        };
        match result {
            Ok(v) => return Ok(v),
            Err(e) => {
                if !policy.should_retry(&e, attempt) {
                    return Err(if attempt == 0 {
                        e
                    } else {
                        ClientError::RetriesExhausted {
                            attempts: attempt + 1,
                            total_backoff,
                            last_addr: None,
                            last: Box::new(e),
                        }
                    });
                }
                if matches!(e, ClientError::Wire(_)) {
                    // Transport state is unknowable: reconnect.
                    conn = None;
                }
                let backoff = policy.backoff(attempt);
                total_backoff += backoff;
                sleep(backoff);
                attempt += 1;
            }
        }
    }
}

/// Submit + fetch one request with retries: each attempt connects fresh
/// if needed and runs [`FrontClient::call`]. Safe to retry because SpMM
/// requests are pure reads of the registered image. An exhausted budget
/// comes back as [`ClientError::RetriesExhausted`] with `addr` stamped
/// as the last failing address.
#[allow(clippy::too_many_arguments)]
pub fn call_with_retry(
    policy: &RetryPolicy,
    addr: &str,
    timeout: Duration,
    image: &ImageInfo,
    n: usize,
    alpha: f32,
    beta: f32,
    b: &[f32],
    c: &[f32],
    col_block: usize,
) -> Result<FrontResponse, ClientError> {
    retry_loop(
        policy,
        || FrontClient::connect(addr, timeout),
        |client| client.call(image, n, alpha, beta, b, c, col_block),
        std::thread::sleep,
    )
    .map_err(|e| match e {
        ClientError::RetriesExhausted { attempts, total_backoff, last, .. } => {
            ClientError::RetriesExhausted {
                attempts,
                total_backoff,
                last_addr: Some(addr.to_string()),
                last,
            }
        }
        other => other,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::WireError;
    use crate::serve_net::proto::ShedReason;

    fn wire_err() -> ClientError {
        ClientError::Wire(WireError::Malformed("boom".into()))
    }

    fn shed_err() -> ClientError {
        ClientError::Shed { reason: ShedReason::QueueFull, message: "full".into() }
    }

    #[test]
    fn backoff_schedule_doubles_jitters_and_caps() {
        let p = RetryPolicy::default();
        for attempt in 0..8 {
            let full = Duration::from_millis(25 * (1 << attempt)).min(p.max_delay);
            let d = p.backoff(attempt);
            assert!(
                d >= full / 2 && d <= full,
                "attempt {attempt}: {d:?} outside [{:?}, {full:?}]",
                full / 2
            );
            assert_eq!(d, p.backoff(attempt), "schedule must be deterministic");
        }
        // Far attempts stay at the cap.
        assert!(p.backoff(40) <= p.max_delay);
        assert!(p.backoff(40) >= p.max_delay / 2);
        // Different seeds decorrelate.
        let other = RetryPolicy { seed: 1, ..RetryPolicy::default() };
        assert_ne!(p.backoff(3), other.backoff(3));
    }

    #[test]
    fn classification_wire_yes_shed_opt_in_server_never() {
        let p = RetryPolicy::default();
        assert!(p.should_retry(&wire_err(), 0));
        assert!(!p.should_retry(&wire_err(), p.max_retries), "budget exhausted");
        assert!(!p.should_retry(&shed_err(), 0), "sheds are backpressure, not failures");
        assert!(!p.should_retry(&ClientError::Server("bad".into()), 0));
        let opted = RetryPolicy { retry_sheds: true, ..RetryPolicy::default() };
        assert!(opted.should_retry(&shed_err(), 0));
        assert!(!opted.should_retry(&ClientError::Server("bad".into()), 0));
    }

    #[test]
    fn wire_errors_reconnect_and_succeed_within_budget() {
        let p = RetryPolicy::default();
        let mut connects = 0u32;
        let mut calls = 0u32;
        let mut sleeps: Vec<Duration> = Vec::new();
        let out = retry_loop(
            &p,
            || {
                connects += 1;
                Ok(connects)
            },
            |conn| {
                calls += 1;
                if calls <= 2 {
                    Err(wire_err())
                } else {
                    Ok(*conn)
                }
            },
            |d| sleeps.push(d),
        )
        .unwrap();
        assert_eq!(calls, 3);
        assert_eq!(connects, 3, "every wire error must force a reconnect");
        assert_eq!(out, 3, "the successful call ran on the freshest connection");
        assert_eq!(sleeps, vec![p.backoff(0), p.backoff(1)]);
    }

    #[test]
    fn exhausted_budget_returns_retry_telemetry_with_the_last_error() {
        let p = RetryPolicy { max_retries: 2, ..RetryPolicy::default() };
        let mut calls = 0u32;
        let mut sleeps = 0u32;
        let err = retry_loop(
            &p,
            || Ok(()),
            |_conn: &mut ()| -> Result<(), ClientError> {
                calls += 1;
                Err(wire_err())
            },
            |_| sleeps += 1,
        )
        .unwrap_err();
        let ClientError::RetriesExhausted { attempts, total_backoff, last_addr, last } = err
        else {
            panic!("an exhausted retry budget must carry its telemetry");
        };
        assert_eq!(attempts, 1 + p.max_retries, "first attempt plus max_retries");
        assert_eq!(total_backoff, p.backoff(0) + p.backoff(1));
        assert_eq!(last_addr, None, "retry_loop is address-agnostic");
        assert!(matches!(*last, ClientError::Wire(_)));
        assert!(matches!(last.terminal(), ClientError::Wire(_)));
        assert_eq!(calls, 1 + p.max_retries);
        assert_eq!(sleeps, p.max_retries);
    }

    #[test]
    fn sheds_fail_fast_by_default_and_connect_errors_retry() {
        let p = RetryPolicy::default();
        let mut calls = 0u32;
        let err = retry_loop(
            &p,
            || Ok(()),
            |_conn: &mut ()| -> Result<(), ClientError> {
                calls += 1;
                Err(shed_err())
            },
            |_| panic!("a default-policy shed must not back off"),
        )
        .unwrap_err();
        assert!(matches!(err, ClientError::Shed { .. }));
        assert_eq!(calls, 1);
        // Failures in connect() itself consume the same retry budget.
        let mut connects = 0u32;
        let mut sleeps = 0u32;
        let out: Result<u32, _> = retry_loop(
            &p,
            || {
                connects += 1;
                if connects < 3 {
                    Err(wire_err())
                } else {
                    Ok(connects)
                }
            },
            |conn| Ok(*conn),
            |_| sleeps += 1,
        );
        assert_eq!(out.unwrap(), 3);
        assert_eq!(sleeps, 2);
    }
}
