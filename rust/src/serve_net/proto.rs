//! Payload codecs for the front-door opcodes (`Op::RegisterBegin` through
//! `Op::FrontStatus`, plus the `Chunk`/`Shed` reply frames).
//!
//! Layered on [`crate::net::wire`]'s framing and byte primitives — the
//! magic/version gate, the length-prefixed frames, and the bounds-checked
//! [`ByteWriter`]/[`ByteReader`] pair — so the front door speaks the same
//! wire dialect as the worker fleet and inherits its hostile-bytes
//! guarantees (truncation, trailing garbage, and bad enum values are all
//! typed [`WireError`]s, never panics).
//!
//! Panels cross the wire in **column blocks**: a block of `ncols` columns
//! starting at `col0`, laid out row-major within the block (`value(r,
//! col0 + j)` at index `r * ncols + j`). Registration streams the encoded
//! [`crate::sched::ScheduledMatrix`] image as raw byte ranges for the
//! same reason — no single frame need hold the whole artifact.

use crate::net::wire::{ByteReader, ByteWriter, WireError};

/// Why the front door refused work — the one-byte reason code carried in
/// an `Op::Shed` frame. Distinct from `Op::Err`: a shed is backpressure
/// (retry later, against the same healthy server), not failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum ShedReason {
    /// The admission gate's global in-flight bound is full.
    QueueFull = 0,
    /// The target image is at its per-image fairness quota.
    ImageQuota = 1,
    /// The server is draining: in-flight requests finish, new ones shed.
    Draining = 2,
    /// The accept-side connection gate is full.
    ConnectionLimit = 3,
    /// The request's absolute deadline passed before an execute could
    /// run — shed at admission, batch dequeue, or dispatch pickup.
    DeadlineExceeded = 4,
}

impl ShedReason {
    /// Decode a reason code, rejecting unknown values.
    pub fn from_u8(v: u8) -> Result<ShedReason, WireError> {
        Ok(match v {
            0 => ShedReason::QueueFull,
            1 => ShedReason::ImageQuota,
            2 => ShedReason::Draining,
            3 => ShedReason::ConnectionLimit,
            4 => ShedReason::DeadlineExceeded,
            other => {
                return Err(WireError::Malformed(format!("unknown shed reason {other}")))
            }
        })
    }

    /// Stable label used in logs and reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::ImageQuota => "image_quota",
            ShedReason::Draining => "draining",
            ShedReason::ConnectionLimit => "connection_limit",
            ShedReason::DeadlineExceeded => "deadline_exceeded",
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Encode an `Op::Shed` reply: reason code + message.
pub fn encode_shed(reason: ShedReason, message: &str) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u8(reason as u8);
    w.put_str(message);
    w.into_bytes()
}

/// Decode an `Op::Shed` reply.
pub fn decode_shed(bytes: &[u8]) -> Result<(ShedReason, String), WireError> {
    let mut r = ByteReader::new(bytes);
    let reason = ShedReason::from_u8(r.u8()?)?;
    let message = r.str()?;
    r.finish()?;
    Ok((reason, message))
}

// ---------------------------------------------------------------------------
// Streamed image registration
// ---------------------------------------------------------------------------

/// Encode a RegisterBegin request: total encoded-image byte count.
pub fn encode_register_begin(total_bytes: u64) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(total_bytes);
    w.into_bytes()
}

/// Decode a RegisterBegin request.
pub fn decode_register_begin(bytes: &[u8]) -> Result<u64, WireError> {
    let mut r = ByteReader::new(bytes);
    let total = r.u64()?;
    r.finish()?;
    Ok(total)
}

/// Encode a RegisterChunk request: upload token, byte offset, raw image
/// bytes (the chunk runs to the end of the payload).
pub fn encode_register_chunk(token: u64, offset: u64, chunk: &[u8]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(token);
    w.put_u64(offset);
    let mut bytes = w.into_bytes();
    bytes.extend_from_slice(chunk);
    bytes
}

/// Decode a RegisterChunk request into (token, offset, chunk bytes).
pub fn decode_register_chunk(bytes: &[u8]) -> Result<(u64, u64, &[u8]), WireError> {
    let mut r = ByteReader::new(bytes);
    let token = r.u64()?;
    let offset = r.u64()?;
    let chunk = r.take(r.remaining())?;
    Ok((token, offset, chunk))
}

/// A registered image as the front door reports it back: the id to submit
/// against plus the dimensions the client needs to shape B/C panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ImageInfo {
    /// Server-assigned image id.
    pub id: u64,
    /// Rows of A (C has `m` rows).
    pub m: u64,
    /// Columns of A (B has `k` rows).
    pub k: u64,
}

/// Encode a RegisterEnd success reply.
pub fn encode_register_ok(info: &ImageInfo) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(info.id);
    w.put_u64(info.m);
    w.put_u64(info.k);
    w.into_bytes()
}

/// Decode a RegisterEnd success reply.
pub fn decode_register_ok(bytes: &[u8]) -> Result<ImageInfo, WireError> {
    let mut r = ByteReader::new(bytes);
    let info = ImageInfo { id: r.u64()?, m: r.u64()?, k: r.u64()? };
    r.finish()?;
    Ok(info)
}

// ---------------------------------------------------------------------------
// Chunked submit
// ---------------------------------------------------------------------------

/// Encode a Submit request: image id, N, scalars, and the request's
/// deadline budget in milliseconds (`0` = no deadline). The server stamps
/// an absolute deadline at receipt and the pipeline checks it at
/// admission, batch dequeue, and dispatch pickup. Panels follow in
/// SubmitChunk frames.
pub fn encode_submit(
    image_id: u64,
    n: usize,
    alpha: f32,
    beta: f32,
    deadline_ms: u64,
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(image_id);
    w.put_u64(n as u64);
    w.put_f32(alpha);
    w.put_f32(beta);
    w.put_u64(deadline_ms);
    w.into_bytes()
}

/// Decode a Submit request into (image id, n, alpha, beta, deadline_ms).
pub fn decode_submit(bytes: &[u8]) -> Result<(u64, usize, f32, f32, u64), WireError> {
    let mut r = ByteReader::new(bytes);
    let id = r.u64()?;
    let n = r.len64()?;
    let alpha = r.f32()?;
    let beta = r.f32()?;
    let deadline_ms = r.u64()?;
    r.finish()?;
    Ok((id, n, alpha, beta, deadline_ms))
}

/// Encode a SubmitChunk request: one column block of the B and C panels.
/// `b_block` is `k × ncols` and `c_block` is `m × ncols`, both row-major
/// within the block.
pub fn encode_submit_chunk(
    ticket: u64,
    col0: u64,
    ncols: u64,
    b_block: &[f32],
    c_block: &[f32],
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(ticket);
    w.put_u64(col0);
    w.put_u64(ncols);
    w.put_f32_slice(b_block);
    w.put_f32_slice(c_block);
    w.into_bytes()
}

/// Decode a SubmitChunk request into (ticket, col0, ncols, b, c).
#[allow(clippy::type_complexity)]
pub fn decode_submit_chunk(
    bytes: &[u8],
) -> Result<(u64, u64, u64, Vec<f32>, Vec<f32>), WireError> {
    let mut r = ByteReader::new(bytes);
    let ticket = r.u64()?;
    let col0 = r.u64()?;
    let ncols = r.u64()?;
    let b = r.f32_slice()?;
    let c = r.f32_slice()?;
    r.finish()?;
    Ok((ticket, col0, ncols, b, c))
}

/// Encode a one-`u64` payload (SubmitEnd / Poll tickets, Submit's ticket
/// reply, RegisterBegin's token reply).
pub fn encode_u64(v: u64) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(v);
    w.into_bytes()
}

/// Decode a one-`u64` payload.
pub fn decode_u64(bytes: &[u8]) -> Result<u64, WireError> {
    let mut r = ByteReader::new(bytes);
    let v = r.u64()?;
    r.finish()?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Streamed response
// ---------------------------------------------------------------------------

/// Encode an Await request: ticket + the column-block size the client
/// wants the result streamed in (0 = one chunk).
pub fn encode_await(ticket: u64, chunk_cols: u64) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(ticket);
    w.put_u64(chunk_cols);
    w.into_bytes()
}

/// Decode an Await request into (ticket, chunk_cols).
pub fn decode_await(bytes: &[u8]) -> Result<(u64, u64), WireError> {
    let mut r = ByteReader::new(bytes);
    let ticket = r.u64()?;
    let chunk_cols = r.u64()?;
    r.finish()?;
    Ok((ticket, chunk_cols))
}

/// Encode an `Op::Chunk` reply element: one column block of the result C
/// panel (`m × ncols`, row-major within the block).
pub fn encode_result_chunk(col0: u64, ncols: u64, c_block: &[f32]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(col0);
    w.put_u64(ncols);
    w.put_f32_slice(c_block);
    w.into_bytes()
}

/// Decode an `Op::Chunk` reply element into (col0, ncols, c block).
pub fn decode_result_chunk(bytes: &[u8]) -> Result<(u64, u64, Vec<f32>), WireError> {
    let mut r = ByteReader::new(bytes);
    let col0 = r.u64()?;
    let ncols = r.u64()?;
    let c = r.f32_slice()?;
    r.finish()?;
    Ok((col0, ncols, c))
}

/// The closing frame of an Await reply: the pipeline's per-stage timing
/// for the request (nanoseconds, stamped from the same `Instant`s as the
/// server-side `RequestTiming`), plus the backend that served it and the
/// pipeline error if it failed (the C chunks are then absent).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AwaitOk {
    /// Queue-stage nanoseconds.
    pub queue_ns: u64,
    /// Batch-stage nanoseconds.
    pub batch_ns: u64,
    /// Prepare-stage nanoseconds.
    pub prepare_ns: u64,
    /// Execute-stage nanoseconds.
    pub exec_ns: u64,
    /// FLOPs the request performed.
    pub flops: u64,
    /// Backend name that served the request.
    pub backend: String,
    /// Pipeline failure, if any.
    pub error: Option<String>,
}

/// Encode the closing Await `Op::Ok` frame.
pub fn encode_await_ok(ok: &AwaitOk) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(ok.queue_ns);
    w.put_u64(ok.batch_ns);
    w.put_u64(ok.prepare_ns);
    w.put_u64(ok.exec_ns);
    w.put_u64(ok.flops);
    w.put_str(&ok.backend);
    match &ok.error {
        None => w.put_u8(0),
        Some(e) => {
            w.put_u8(1);
            w.put_str(e);
        }
    }
    w.into_bytes()
}

/// Decode the closing Await `Op::Ok` frame.
pub fn decode_await_ok(bytes: &[u8]) -> Result<AwaitOk, WireError> {
    let mut r = ByteReader::new(bytes);
    let mut ok = AwaitOk {
        queue_ns: r.u64()?,
        batch_ns: r.u64()?,
        prepare_ns: r.u64()?,
        exec_ns: r.u64()?,
        flops: r.u64()?,
        backend: r.str()?,
        error: None,
    };
    if r.u8()? != 0 {
        ok.error = Some(r.str()?);
    }
    r.finish()?;
    Ok(ok)
}

// ---------------------------------------------------------------------------
// Status
// ---------------------------------------------------------------------------

/// What a FrontStatus probe reports: identity and load of the listening
/// front door.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FrontStatus {
    /// Backend spec the coordinator behind this front door executes on.
    pub backend_spec: String,
    /// True once a Drain was received: new submits shed.
    pub draining: bool,
    /// Images registered so far.
    pub images: u64,
    /// Tickets currently open (submitted, not yet fetched).
    pub open_tickets: u64,
    /// Requests whose responses have been streamed back.
    pub completed: u64,
}

/// Encode a FrontStatus success reply.
pub fn encode_status_ok(s: &FrontStatus) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_str(&s.backend_spec);
    w.put_u8(s.draining as u8);
    w.put_u64(s.images);
    w.put_u64(s.open_tickets);
    w.put_u64(s.completed);
    w.into_bytes()
}

/// Decode a FrontStatus success reply.
pub fn decode_status_ok(bytes: &[u8]) -> Result<FrontStatus, WireError> {
    let mut r = ByteReader::new(bytes);
    let s = FrontStatus {
        backend_spec: r.str()?,
        draining: r.u8()? != 0,
        images: r.u64()?,
        open_tickets: r.u64()?,
        completed: r.u64()?,
    };
    r.finish()?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shed_roundtrip_and_bad_reason_rejected() {
        let bytes = encode_shed(ShedReason::ImageQuota, "image 3 at quota 2");
        let (reason, msg) = decode_shed(&bytes).unwrap();
        assert_eq!(reason, ShedReason::ImageQuota);
        assert_eq!(msg, "image 3 at quota 2");
        let mut evil = bytes.clone();
        evil[0] = 99;
        assert!(matches!(decode_shed(&evil).unwrap_err(), WireError::Malformed(_)));
    }

    #[test]
    fn register_codecs_roundtrip() {
        assert_eq!(decode_register_begin(&encode_register_begin(4096)).unwrap(), 4096);
        let (token, offset, chunk) =
            decode_register_chunk(&encode_register_chunk(7, 128, &[1, 2, 3])).unwrap();
        assert_eq!((token, offset, chunk), (7, 128, &[1u8, 2, 3][..]));
        let info = ImageInfo { id: 5, m: 48, k: 32 };
        assert_eq!(decode_register_ok(&encode_register_ok(&info)).unwrap(), info);
    }

    #[test]
    fn submit_codecs_roundtrip() {
        let (id, n, alpha, beta, deadline_ms) =
            decode_submit(&encode_submit(9, 4, 1.5, -0.5, 250)).unwrap();
        assert_eq!((id, n, alpha, beta, deadline_ms), (9, 4, 1.5, -0.5, 250));
        let b = vec![1.0f32, 2.0, 3.0, 4.0];
        let c = vec![-1.0f32, -2.0];
        let (t, col0, ncols, b2, c2) =
            decode_submit_chunk(&encode_submit_chunk(11, 2, 2, &b, &c)).unwrap();
        assert_eq!((t, col0, ncols), (11, 2, 2));
        assert_eq!(b2, b);
        assert_eq!(c2, c);
        assert_eq!(decode_u64(&encode_u64(u64::MAX)).unwrap(), u64::MAX);
    }

    #[test]
    fn await_codecs_roundtrip() {
        assert_eq!(decode_await(&encode_await(3, 8)).unwrap(), (3, 8));
        let (col0, ncols, c) =
            decode_result_chunk(&encode_result_chunk(4, 2, &[0.5, -0.5])).unwrap();
        assert_eq!((col0, ncols), (4, 2));
        assert_eq!(c, vec![0.5, -0.5]);
        let ok = AwaitOk {
            queue_ns: 1,
            batch_ns: 2,
            prepare_ns: 3,
            exec_ns: 4,
            flops: 1000,
            backend: "functional".into(),
            error: None,
        };
        assert_eq!(decode_await_ok(&encode_await_ok(&ok)).unwrap(), ok);
        let failed = AwaitOk { error: Some("boom".into()), ..ok };
        assert_eq!(decode_await_ok(&encode_await_ok(&failed)).unwrap(), failed);
    }

    #[test]
    fn status_roundtrip_and_trailing_garbage_rejected() {
        let s = FrontStatus {
            backend_spec: "native:4".into(),
            draining: true,
            images: 3,
            open_tickets: 2,
            completed: 41,
        };
        assert_eq!(decode_status_ok(&encode_status_ok(&s)).unwrap(), s);
        let mut bytes = encode_status_ok(&s);
        bytes.push(0);
        assert!(matches!(decode_status_ok(&bytes).unwrap_err(), WireError::Malformed(_)));
    }
}
