//! Open-loop load generator for the network front door.
//!
//! **Open loop** means arrivals are scheduled on a clock (`rate` req/s
//! over `duration`), not gated on responses — exactly the discipline that
//! exposes queueing collapse: a closed-loop generator slows down with the
//! server and hides the knee, an open-loop one keeps offering load and
//! lets the admission gate do its job. What comes back is therefore a mix
//! of completions, typed sheds, and (rarely) errors, all of which this
//! module counts separately.
//!
//! The report carries exact per-stage percentiles (server-measured queue
//! / batch / prepare / exec plus client-observed end-to-end), shed counts
//! by [`ShedReason`], the client-side in-flight peak, and a per-image
//! completion census — and serializes into the schema-v1
//! [`BenchRecord`] trajectory (`BENCH_serve_<name>.json`) so serving
//! latency regresses loudly, the same way kernel throughput does.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::client::{ClientError, FrontClient};
use super::proto::{ImageInfo, ShedReason};
use crate::sched::{preprocess, ScheduledMatrix};
use crate::sparse::catalog::{Family, MatrixSpec};
use crate::sparse::rng::Rng;
use crate::sparse::{gen, Coo};
use crate::telemetry::bench_record::{BenchMeasurement, BenchRecord, ScalingPoint};

/// Which structural family the generated request matrices come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mix {
    /// Zipf row degrees ([`gen::power_law_rows`]) — a few hot rows, the
    /// skew that trips per-PE imbalance.
    PowerLaw,
    /// Banded FEM-style ([`gen::banded`]).
    Banded,
    /// Uniform random ([`gen::random_uniform`]).
    Uniform,
}

impl Mix {
    /// Parse a `--mix` argument.
    pub fn parse(s: &str) -> Option<Mix> {
        match s {
            "power-law" | "powerlaw" | "power_law" => Some(Mix::PowerLaw),
            "banded" => Some(Mix::Banded),
            "uniform" => Some(Mix::Uniform),
            _ => None,
        }
    }

    /// Stable name (reports, file names).
    pub fn name(&self) -> &'static str {
        match self {
            Mix::PowerLaw => "power-law",
            Mix::Banded => "banded",
            Mix::Uniform => "uniform",
        }
    }

    /// Catalog family this mix models.
    pub fn family(&self) -> Family {
        match self {
            Mix::PowerLaw => Family::SsPowerRows,
            Mix::Banded => Family::SsBanded,
            Mix::Uniform => Family::SsUniform,
        }
    }

    /// Generate one image's sparse matrix.
    fn generate(&self, m: usize, k: usize, nnz: usize, rng: &mut Rng) -> Coo {
        match self {
            Mix::PowerLaw => gen::power_law_rows(m, k, nnz, 1.2, rng),
            Mix::Banded => {
                let n = m.min(k);
                let row_nnz = (nnz / n.max(1)).max(1);
                gen::banded(n, n / 16 + 1, row_nnz, rng)
            }
            Mix::Uniform => {
                let density = nnz as f64 / (m as f64 * k as f64);
                gen::random_uniform(m, k, density, rng)
            }
        }
    }
}

/// Everything a loadgen run is parameterized by.
#[derive(Clone)]
pub struct LoadgenOptions {
    /// Front door address (`host:port`).
    pub addr: String,
    /// Offered arrival rate, requests per second.
    pub rate: f64,
    /// How long to keep offering.
    pub duration: Duration,
    /// Matrix mix for the registered images.
    pub mix: Mix,
    /// How many distinct images to register and spread load over.
    pub images: usize,
    /// Fraction of requests aimed at image 0 (on top of its round-robin
    /// share) — models one hot tenant. 0 = even spread.
    pub hot: f64,
    /// Rows per image.
    pub m: usize,
    /// Columns per image.
    pub k: usize,
    /// Dense columns per request.
    pub n: usize,
    /// Non-zeros per image.
    pub nnz: usize,
    /// Generator seed.
    pub seed: u64,
    /// Column-block width for panel upload / result download (0 = one
    /// block).
    pub col_block: usize,
    /// Sender threads (each owns one connection). Bounds client-side
    /// concurrency; an open-loop arrival finding every sender busy still
    /// waits its turn, which the e2e percentile then reflects.
    pub senders: usize,
    /// Per-connection socket timeout.
    pub timeout: Duration,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            addr: "127.0.0.1:7700".to_string(),
            rate: 50.0,
            duration: Duration::from_secs(2),
            mix: Mix::PowerLaw,
            images: 4,
            hot: 0.0,
            m: 256,
            k: 256,
            n: 16,
            nnz: 4096,
            seed: 0x5EED,
            col_block: 0,
            senders: 8,
            timeout: Duration::from_secs(30),
        }
    }
}

/// Exact percentiles over one stage's samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    /// Samples.
    pub count: usize,
    /// Median, ns.
    pub p50_ns: u64,
    /// 95th percentile, ns.
    pub p95_ns: u64,
    /// 99th percentile, ns.
    pub p99_ns: u64,
}

impl StageStats {
    fn from_samples(mut ns: Vec<u64>) -> StageStats {
        if ns.is_empty() {
            return StageStats::default();
        }
        ns.sort_unstable();
        let pct = |q: f64| ns[((ns.len() - 1) as f64 * q).round() as usize];
        StageStats { count: ns.len(), p50_ns: pct(0.50), p95_ns: pct(0.95), p99_ns: pct(0.99) }
    }
}

/// What one loadgen run observed.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests the open loop offered.
    pub offered: usize,
    /// Requests that completed with a result.
    pub completed: usize,
    /// Typed sheds by reason, `[queue_full, image_quota, draining,
    /// connection_limit, deadline_exceeded]`.
    pub sheds: [usize; 5],
    /// Non-shed failures.
    pub errors: usize,
    /// Server-measured admission→batch wait.
    pub queue: StageStats,
    /// Server-measured batch→worker wait.
    pub batch: StageStats,
    /// Server-measured residency/prepare time.
    pub prepare: StageStats,
    /// Server-measured executor time.
    pub exec: StageStats,
    /// Client-observed submit→result latency.
    pub e2e: StageStats,
    /// Peak simultaneously outstanding requests, client side.
    pub concurrency_peak: usize,
    /// Completions per image id, sorted by id.
    pub completed_by_image: Vec<(u64, usize)>,
    /// Mean FLOP per completed request (for throughput conversion).
    pub flops_per_request: f64,
    /// Wall-clock of the offering window plus drain.
    pub wall: Duration,
    /// The image specs this run generated (for the bench record).
    pub matrices: Vec<MatrixSpec>,
    /// Mix name (for the bench record).
    pub mix: &'static str,
    /// Dense columns per request (for the bench record).
    pub n: usize,
}

impl LoadReport {
    /// Total sheds across all reasons.
    pub fn shed_total(&self) -> usize {
        self.sheds.iter().sum()
    }

    /// The five stage rows, named.
    pub fn stage_rows(&self) -> [(&'static str, &StageStats); 5] {
        [
            ("queue", &self.queue),
            ("batch", &self.batch),
            ("prepare", &self.prepare),
            ("exec", &self.exec),
            ("e2e", &self.e2e),
        ]
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "offered {} | completed {} | shed {} (queue {}, quota {}, drain {}, conn {}, \
             deadline {}) | errors {}\n",
            self.offered,
            self.completed,
            self.shed_total(),
            self.sheds[0],
            self.sheds[1],
            self.sheds[2],
            self.sheds[3],
            self.sheds[4],
            self.errors,
        ));
        out.push_str(&format!(
            "concurrency peak {} | wall {:.2}s\n",
            self.concurrency_peak,
            self.wall.as_secs_f64()
        ));
        out.push_str("stage      p50          p95          p99\n");
        for (name, s) in self.stage_rows() {
            out.push_str(&format!(
                "{name:<9} {:>10.3}ms {:>10.3}ms {:>10.3}ms\n",
                s.p50_ns as f64 / 1e6,
                s.p95_ns as f64 / 1e6,
                s.p99_ns as f64 / 1e6,
            ));
        }
        for (id, count) in &self.completed_by_image {
            out.push_str(&format!("image {id}: {count} completed\n"));
        }
        out
    }

    /// Serialize into the schema-v1 perf trajectory. Stage rows land in
    /// `results` as `serve/<stage>` with GFLOP/s derived from the stage
    /// p50 (nonzero whenever anything completed, so the zeroed-baseline
    /// guard still bites); shed counts ride in `scaling` under
    /// `serve/sheds` where self-comparison never flags them.
    pub fn to_bench_record(&self, name: &str, timestamp: &str) -> BenchRecord {
        let matrix = format!("mix:{}", self.mix);
        let results = self
            .stage_rows()
            .iter()
            .map(|(stage, s)| BenchMeasurement {
                bench: format!("serve/{stage}"),
                matrix: matrix.clone(),
                n: self.n,
                // FLOP / ns == GFLOP/s; stages with a ~0 p50 (cache-hit
                // prepare) get clamped to 1 ns rather than dividing by 0.
                gflops: if s.count == 0 {
                    0.0
                } else {
                    self.flops_per_request / s.p50_ns.max(1) as f64
                },
                median_ns: s.p50_ns as f64,
                p50_ns: s.p50_ns as f64,
                p95_ns: s.p95_ns as f64,
                p99_ns: s.p99_ns as f64,
            })
            .collect();
        let goodput = self.completed as f64 / (self.offered.max(1)) as f64;
        let scaling = vec![
            ScalingPoint {
                bench: "serve/concurrency".to_string(),
                workers: self.concurrency_peak.max(1),
                gflops: self.flops_per_request * self.completed as f64
                    / self.wall.as_nanos().max(1) as f64,
                efficiency: goodput,
            },
            ScalingPoint {
                bench: "serve/sheds".to_string(),
                workers: 1,
                gflops: self.shed_total() as f64,
                // Constant so a strict self-compare can never flag this
                // row; the shed count itself lives in gflops, which
                // compare() ignores for scaling points.
                efficiency: 1.0,
            },
        ];
        BenchRecord {
            name: name.to_string(),
            git_rev: crate::telemetry::bench_record::git_rev(),
            timestamp: timestamp.to_string(),
            host_threads: std::thread::available_parallelism().map_or(1, |p| p.get()),
            matrices: self.matrices.clone(),
            results,
            scaling,
        }
    }
}

/// One scheduled arrival.
struct Job {
    due: Instant,
    image_idx: usize,
}

/// One finished arrival.
enum Outcome {
    Done { image: u64, queue_ns: u64, batch_ns: u64, prepare_ns: u64, exec_ns: u64, e2e_ns: u64, flops: u64 },
    Shed(ShedReason),
    Error,
}

/// Client-side in-flight gauge (current + high-water mark).
#[derive(Default)]
struct Gauge {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl Gauge {
    fn enter(&self) {
        let now = self.current.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }
    fn exit(&self) {
        self.current.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Generate + register the image set; returns (infos, specs, panels).
#[allow(clippy::type_complexity)]
fn build_images(
    control: &mut FrontClient,
    opts: &LoadgenOptions,
) -> Result<(Vec<ImageInfo>, Vec<MatrixSpec>, Vec<(Vec<f32>, Vec<f32>)>), ClientError> {
    let mut infos = Vec::with_capacity(opts.images);
    let mut specs = Vec::with_capacity(opts.images);
    let mut panels = Vec::with_capacity(opts.images);
    for i in 0..opts.images {
        let seed = opts.seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let coo = opts.mix.generate(opts.m, opts.k, opts.nnz, &mut rng);
        let image = schedule_default(&coo);
        let info = control.register_image(&image, 1 << 16)?;
        let (k, m) = (info.k as usize, info.m as usize);
        let b: Vec<f32> = (0..k * opts.n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..m * opts.n).map(|_| rng.normal()).collect();
        specs.push(MatrixSpec {
            name: format!("loadgen-{}-{i}", opts.mix.name()),
            family: opts.mix.family(),
            m: coo.m,
            k: coo.k,
            nnz: coo.nnz(),
            seed,
        });
        infos.push(info);
        panels.push((b, c));
    }
    Ok((infos, specs, panels))
}

/// The default schedule shape loadgen registers images with (P=8 PEs,
/// K0=64 column windows, distance-4 hazard window).
pub fn schedule_default(coo: &Coo) -> ScheduledMatrix {
    preprocess(coo, 8, 64, 4)
}

/// Run one open-loop load test against a front door.
pub fn run(opts: &LoadgenOptions) -> Result<LoadReport, ClientError> {
    let mut control = FrontClient::connect(&opts.addr, opts.timeout)?;
    let (infos, specs, panels) = build_images(&mut control, opts)?;
    let infos = Arc::new(infos);
    let panels = Arc::new(panels);

    let total = ((opts.rate * opts.duration.as_secs_f64()).ceil() as usize).max(1);
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (out_tx, out_rx) = mpsc::channel::<Outcome>();
    let gauge = Arc::new(Gauge::default());

    let mut senders = Vec::with_capacity(opts.senders);
    for _ in 0..opts.senders.max(1) {
        let job_rx = Arc::clone(&job_rx);
        let out_tx = out_tx.clone();
        let gauge = Arc::clone(&gauge);
        let infos = Arc::clone(&infos);
        let panels = Arc::clone(&panels);
        let opts = opts.clone();
        senders.push(std::thread::spawn(move || {
            let mut client = FrontClient::connect(&opts.addr, opts.timeout).ok();
            loop {
                let job = match job_rx.lock().unwrap().recv() {
                    Ok(j) => j,
                    Err(_) => return,
                };
                let now = Instant::now();
                if job.due > now {
                    std::thread::sleep(job.due - now);
                }
                if client.is_none() {
                    client = FrontClient::connect(&opts.addr, opts.timeout).ok();
                }
                let Some(conn) = client.as_mut() else {
                    if out_tx.send(Outcome::Error).is_err() {
                        return;
                    }
                    continue;
                };
                let info = &infos[job.image_idx];
                let (b, c) = &panels[job.image_idx];
                gauge.enter();
                let t0 = Instant::now();
                let result =
                    conn.call(info, opts.n, 1.0, 0.5, b, c, opts.col_block);
                let e2e_ns = t0.elapsed().as_nanos() as u64;
                gauge.exit();
                // A transport error leaves the framed stream at an
                // unknown position — e.g. a read timeout mid-Await whose
                // reply frames the server writes later. Reusing the
                // connection would read those frames as the next rpc's
                // reply and silently desync, so drop it and reconnect
                // before the next job.
                if matches!(result, Err(ClientError::Wire(_))) {
                    client = None;
                }
                let outcome = match result {
                    Ok(resp) => match resp.timing.error {
                        None => Outcome::Done {
                            image: info.id,
                            queue_ns: resp.timing.queue_ns,
                            batch_ns: resp.timing.batch_ns,
                            prepare_ns: resp.timing.prepare_ns,
                            exec_ns: resp.timing.exec_ns,
                            e2e_ns,
                            flops: resp.timing.flops,
                        },
                        Some(_) => Outcome::Error,
                    },
                    Err(ClientError::Shed { reason, .. }) => Outcome::Shed(reason),
                    Err(_) => Outcome::Error,
                };
                if out_tx.send(outcome).is_err() {
                    return;
                }
            }
        }));
    }
    drop(out_tx);

    // The open loop: arrivals on the clock, regardless of what came back.
    let t0 = Instant::now();
    let mut hot_credit = 0.0f64;
    for i in 0..total {
        let image_idx = {
            // `hot` extra fraction to image 0, remainder round-robin.
            hot_credit += opts.hot;
            if hot_credit >= 1.0 {
                hot_credit -= 1.0;
                0
            } else {
                i % infos.len()
            }
        };
        let due = t0 + Duration::from_secs_f64(i as f64 / opts.rate.max(0.001));
        if job_tx.send(Job { due, image_idx }).is_err() {
            break;
        }
    }
    drop(job_tx);
    for s in senders {
        let _ = s.join();
    }
    let wall = t0.elapsed();

    // Aggregate.
    let (mut queue, mut batch, mut prepare, mut exec, mut e2e) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
    let mut sheds = [0usize; 5];
    let mut errors = 0usize;
    let mut flops_sum = 0u128;
    let mut by_image: Vec<(u64, usize)> = Vec::new();
    for outcome in out_rx.iter() {
        match outcome {
            Outcome::Done { image, queue_ns, batch_ns, prepare_ns, exec_ns, e2e_ns, flops } => {
                queue.push(queue_ns);
                batch.push(batch_ns);
                prepare.push(prepare_ns);
                exec.push(exec_ns);
                e2e.push(e2e_ns);
                flops_sum += flops as u128;
                match by_image.iter_mut().find(|(id, _)| *id == image) {
                    Some((_, count)) => *count += 1,
                    None => by_image.push((image, 1)),
                }
            }
            Outcome::Shed(reason) => sheds[reason as usize] += 1,
            Outcome::Error => errors += 1,
        }
    }
    by_image.sort_by_key(|&(id, _)| id);
    let completed = e2e.len();
    Ok(LoadReport {
        offered: total,
        completed,
        sheds,
        errors,
        queue: StageStats::from_samples(queue),
        batch: StageStats::from_samples(batch),
        prepare: StageStats::from_samples(prepare),
        exec: StageStats::from_samples(exec),
        e2e: StageStats::from_samples(e2e),
        concurrency_peak: gauge.peak.load(Ordering::SeqCst),
        completed_by_image: by_image,
        flops_per_request: flops_sum as f64 / completed.max(1) as f64,
        wall,
        matrices: specs,
        mix: opts.mix.name(),
        n: opts.n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_parses_and_names_round_trip() {
        for mix in [Mix::PowerLaw, Mix::Banded, Mix::Uniform] {
            assert_eq!(Mix::parse(mix.name()), Some(mix));
        }
        assert_eq!(Mix::parse("power_law"), Some(Mix::PowerLaw));
        assert_eq!(Mix::parse("bogus"), None);
    }

    #[test]
    fn stage_stats_exact_percentiles() {
        let s = StageStats::from_samples((1..=100).collect());
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_ns, 51, "round((100-1)*0.5) = 50 -> sorted[50] = 51");
        assert_eq!(s.p95_ns, 95);
        assert_eq!(s.p99_ns, 99);
        assert_eq!(StageStats::from_samples(Vec::new()).count, 0);
    }

    #[test]
    fn bench_record_is_schema_v1_and_not_zeroed() {
        let report = LoadReport {
            offered: 10,
            completed: 8,
            sheds: [2, 0, 0, 0, 0],
            errors: 0,
            queue: StageStats { count: 8, p50_ns: 100, p95_ns: 200, p99_ns: 300 },
            batch: StageStats { count: 8, p50_ns: 100, p95_ns: 200, p99_ns: 300 },
            prepare: StageStats { count: 8, p50_ns: 0, p95_ns: 0, p99_ns: 0 },
            exec: StageStats { count: 8, p50_ns: 5_000, p95_ns: 9_000, p99_ns: 9_500 },
            e2e: StageStats { count: 8, p50_ns: 20_000, p95_ns: 40_000, p99_ns: 50_000 },
            concurrency_peak: 3,
            completed_by_image: vec![(1, 8)],
            flops_per_request: 1.0e6,
            wall: Duration::from_secs(1),
            matrices: vec![MatrixSpec {
                name: "loadgen-power-law-0".into(),
                family: Family::SsPowerRows,
                m: 64,
                k: 64,
                nnz: 512,
                seed: 7,
            }],
            mix: "power-law",
            n: 8,
        };
        let record = report.to_bench_record("unit", "2026-01-01T00:00:00Z");
        assert!(!record.is_zeroed(), "completed runs must not look like placeholders");
        // Round-trip through the schema-v1 parser.
        let parsed =
            BenchRecord::from_value(&record.to_value()).expect("schema v1 round-trip");
        assert_eq!(parsed.results.len(), 5);
        assert_eq!(parsed.scaling.len(), 2);
        let sheds = parsed.scaling.iter().find(|s| s.bench == "serve/sheds").unwrap();
        assert_eq!(sheds.gflops, 2.0, "shed count rides in the scaling gflops column");
        // A strict self-compare never flags its own sheds row.
        assert!(crate::telemetry::bench_record::compare(&record, &record, 0.0).is_empty());
        // The zero-p50 prepare stage must not divide by zero.
        let prep = parsed.results.iter().find(|r| r.bench == "serve/prepare").unwrap();
        assert!(prep.gflops.is_finite());
    }

    #[test]
    fn hot_fraction_routes_extra_load_to_image_zero() {
        // Reproduce the router loop's arithmetic: 50% hot over 8 images.
        let (mut hot_credit, mut zero) = (0.0f64, 0usize);
        let total = 1000;
        for i in 0..total {
            hot_credit += 0.5;
            let idx = if hot_credit >= 1.0 {
                hot_credit -= 1.0;
                0
            } else {
                i % 8
            };
            if idx == 0 {
                zero += 1;
            }
        }
        assert!(zero > total / 2, "image 0 gets its round-robin share plus the hot half");
    }
}
