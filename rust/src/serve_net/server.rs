//! The network front door: a socket server in front of
//! [`crate::coordinator::Server`].
//!
//! One [`FrontDoor`] owns a listener plus a full four-stage serving
//! pipeline. Connections get a thread each (the [`crate::net::worker`]
//! model); within a connection, clients stream image registrations and
//! B/C panels in column-block chunks, submit SpMMs, and fetch results as
//! streamed [`Op::Chunk`] frames.
//!
//! Backpressure is wired end to end and always **typed**:
//!
//! * the accept loop itself runs behind an [`AdmissionGate`] — a full
//!   connection gate sheds with an [`Op::Shed`] frame
//!   ([`ShedReason::ConnectionLimit`]) instead of queueing accepts;
//! * a submit the pipeline's admission stage refuses comes back as an
//!   [`Op::Shed`] frame carrying [`ShedReason::QueueFull`] or
//!   [`ShedReason::ImageQuota`] — never an unbounded queue, never a
//!   generic error;
//! * a drained server ([`Op::Drain`]) finishes in-flight work and sheds
//!   new submits with [`ShedReason::Draining`].
//!
//! Every submit opens a `net.frontend` span (submit-begin to
//! response-streamed) and installs it as the submitting thread's span
//! context, so the pipeline's `request` root — and through it every stage
//! span — parents under the network edge that carried the request.

use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::proto::{self, AwaitOk, FrontStatus, ImageInfo, ShedReason};
use crate::coordinator::admission::{Admit, AdmissionGate, AdmissionPolicy};
use crate::coordinator::metrics::Summary;
use crate::coordinator::server::{
    ImageHandle, PipelineConfig, RejectKind, Server, SpmmRequest, SpmmResponse,
};
use crate::net::wire::{self, Op, WireError};
use crate::telemetry::trace::{
    next_span_id, next_trace_id, push_span_context, SpanRecord, TelemetrySink,
};

/// Front-door configuration: the pipeline it fronts plus socket policy.
#[derive(Clone)]
pub struct FrontDoorConfig {
    /// Registry spec the coordinator executes on.
    pub backend_spec: String,
    /// Coordinator worker threads.
    pub workers: usize,
    /// Every pipeline stage's policy (admission bound, per-image quota,
    /// batching window, residency budget, telemetry sink, ...). The sink
    /// here also receives the `net.frontend` spans.
    pub pipeline: PipelineConfig,
    /// Per-connection socket read timeout.
    pub read_timeout: Duration,
    /// Per-connection socket write timeout.
    pub write_timeout: Duration,
    /// Accept-side gate: concurrent connections beyond this shed with a
    /// typed [`ShedReason::ConnectionLimit`] frame at accept.
    pub max_connections: usize,
    /// How long one Await may block on an in-flight request before the
    /// server replies "still running" (the ticket stays fetchable).
    /// Keep this strictly below the clients' read timeout: a client that
    /// gives up mid-Await abandons a connection the server will still
    /// write Chunk/Ok frames to, desyncing any later rpc on it.
    pub await_timeout: Duration,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        FrontDoorConfig {
            backend_spec: "native".to_string(),
            workers: 2,
            pipeline: PipelineConfig::default(),
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            max_connections: 256,
            // Strictly below the 30 s read timeouts (here and in the
            // default clients) so an Await always answers before the
            // peer can time out and abandon the connection mid-reply.
            await_timeout: Duration::from_secs(15),
        }
    }
}

/// One submitted request awaiting pickup: the pipeline's response channel
/// plus the trace/time bookkeeping the `net.frontend` span needs.
struct Ticket {
    rx: Receiver<SpmmResponse>,
    cached: Option<SpmmResponse>,
    trace: Option<(u64, u64)>,
    t_begin: Instant,
    image: u64,
    n: usize,
}

/// Shared state across connection threads.
struct FrontState {
    /// The coordinator lives behind an `Option` so shutdown can take it
    /// by value ([`Server::shutdown`] consumes) after the accept loop
    /// exits; requests hold the read side only for the brief submit call.
    server: RwLock<Option<Server>>,
    spec: String,
    conn_gate: AdmissionGate,
    draining: AtomicBool,
    shutdown: AtomicBool,
    next_token: AtomicU64,
    next_ticket: AtomicU64,
    images: Mutex<HashMap<u64, ImageHandle>>,
    tickets: Mutex<HashMap<u64, Ticket>>,
    completed: AtomicU64,
    sink: Option<Arc<dyn TelemetrySink>>,
}

/// A running front door: the bound listener plus its shared state.
/// Produced by [`FrontDoor::bind`]; [`FrontDoor::run`] serves until a
/// Shutdown frame arrives, then drains the pipeline and returns its
/// serving [`Summary`].
pub struct FrontDoor {
    listener: TcpListener,
    state: Arc<FrontState>,
}

impl FrontDoor {
    /// Start the coordinator pipeline and bind the listener (`host:port`;
    /// port 0 picks a free port — see [`FrontDoor::local_addr`]).
    pub fn bind(addr: &str, config: &FrontDoorConfig) -> std::io::Result<FrontDoor> {
        let sink = config.pipeline.sink.clone();
        let server = Server::start_backend_with(
            config.workers,
            config.pipeline.clone(),
            &config.backend_spec,
        )
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string()))?;
        let listener = TcpListener::bind(addr)?;
        Ok(FrontDoor {
            listener,
            state: Arc::new(FrontState {
                server: RwLock::new(Some(server)),
                spec: config.backend_spec.clone(),
                conn_gate: AdmissionGate::new(AdmissionPolicy {
                    max_in_flight: config.max_connections,
                    per_image_quota: 0,
                }),
                draining: AtomicBool::new(false),
                shutdown: AtomicBool::new(false),
                next_token: AtomicU64::new(1),
                next_ticket: AtomicU64::new(1),
                images: Mutex::new(HashMap::new()),
                tickets: Mutex::new(HashMap::new()),
                completed: AtomicU64::new(0),
                sink,
            }),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept and serve connections until a Shutdown frame arrives, then
    /// drain the pipeline and return its serving summary. Connections
    /// beyond the gate shed at accept with a typed frame; a protocol
    /// error closes that connection only.
    pub fn run(self, config: &FrontDoorConfig) -> std::io::Result<Summary> {
        for conn in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let mut stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let _ = stream.set_read_timeout(Some(config.read_timeout));
            let _ = stream.set_write_timeout(Some(config.write_timeout));
            let _ = stream.set_nodelay(true);
            match self.state.conn_gate.try_admit(0) {
                Admit::Admitted => {
                    let state = Arc::clone(&self.state);
                    let config = config.clone();
                    std::thread::spawn(move || {
                        // Return the slot from a Drop guard so a panic in
                        // serve_connection cannot leak it — leaked slots
                        // would walk the gate down until every accept
                        // sheds with ConnectionLimit.
                        struct SlotGuard(Arc<FrontState>);
                        impl Drop for SlotGuard {
                            fn drop(&mut self) {
                                self.0.conn_gate.release(0);
                            }
                        }
                        let _slot = SlotGuard(Arc::clone(&state));
                        serve_connection(stream, &state, &config);
                    });
                }
                _ => {
                    // Typed shed at the socket edge: the peer learns it
                    // was load, not failure, and the thread budget stays
                    // bounded.
                    let _ = wire::write_frame(
                        &mut stream,
                        Op::Shed,
                        &proto::encode_shed(
                            ShedReason::ConnectionLimit,
                            &format!(
                                "connection limit: {} connections in flight (max {})",
                                self.state.conn_gate.in_flight(),
                                config.max_connections
                            ),
                        ),
                    );
                }
            }
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        let server = self
            .state
            .server
            .write()
            .unwrap()
            .take()
            .expect("front door owns the coordinator until shutdown");
        Ok(server.shutdown())
    }
}

/// A partially uploaded image registration (connection-local: a dead
/// client's half-sent image vanishes with its connection thread).
struct PendingRegister {
    total: usize,
    buf: Vec<u8>,
}

/// A partially uploaded submit: staged panels plus column coverage.
struct PendingSubmit {
    image: ImageHandle,
    n: usize,
    alpha: f32,
    beta: f32,
    b: Vec<f32>,
    c: Vec<f32>,
    covered: Vec<bool>,
    t_begin: Instant,
    /// Absolute deadline stamped at Submit receipt from the frame's
    /// `deadline_ms` budget (`None` when the budget was 0). The upload
    /// time of the panel chunks counts against it.
    deadline: Option<Instant>,
}

/// Per-connection staging for streamed uploads.
#[derive(Default)]
struct ConnStaging {
    regs: HashMap<u64, PendingRegister>,
    subs: HashMap<u64, PendingSubmit>,
}

/// One reply frame.
enum Reply {
    Ok(Vec<u8>),
    Err(String),
    Shed(ShedReason, String),
}

impl Reply {
    fn frame(&self) -> (Op, Vec<u8>) {
        match self {
            Reply::Ok(bytes) => (Op::Ok, bytes.clone()),
            Reply::Err(msg) => (Op::Err, msg.clone().into_bytes()),
            Reply::Shed(reason, msg) => (Op::Shed, proto::encode_shed(*reason, msg)),
        }
    }
}

/// Serve one connection's request loop until EOF, error, or shutdown.
fn serve_connection(mut stream: TcpStream, state: &Arc<FrontState>, config: &FrontDoorConfig) {
    let mut staging = ConnStaging::default();
    loop {
        let (op, payload) = match wire::read_frame_opt(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) | Err(_) => return,
        };
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if op == Op::Await {
            // Await streams its reply (Chunk frames + closing Ok) itself.
            if !handle_await(&mut stream, &payload, state, config) {
                return;
            }
            continue;
        }
        let reply = handle_request(op, &payload, state, &mut staging);
        let (reply_op, reply_payload) = reply.frame();
        if wire::write_frame(&mut stream, reply_op, &reply_payload).is_err() {
            return;
        }
        if op == Op::Shutdown {
            let _ = stream.flush();
            // Unblock the accept loop so `run` observes the flag.
            if let Ok(addr) = stream.local_addr() {
                let _ = TcpStream::connect(addr);
            }
            return;
        }
    }
}

/// Dispatch one single-reply request.
fn handle_request(
    op: Op,
    payload: &[u8],
    state: &Arc<FrontState>,
    staging: &mut ConnStaging,
) -> Reply {
    match run_request(op, payload, state, staging) {
        Ok(reply) => reply,
        Err(e) => Reply::Err(e.to_string()),
    }
}

fn run_request(
    op: Op,
    payload: &[u8],
    state: &Arc<FrontState>,
    staging: &mut ConnStaging,
) -> Result<Reply, WireError> {
    match op {
        Op::Ping => Ok(Reply::Ok(Vec::new())),
        Op::FrontStatus => {
            let status = FrontStatus {
                backend_spec: state.spec.clone(),
                draining: state.draining.load(Ordering::SeqCst),
                images: state.images.lock().unwrap().len() as u64,
                open_tickets: state.tickets.lock().unwrap().len() as u64,
                completed: state.completed.load(Ordering::Relaxed),
            };
            Ok(Reply::Ok(proto::encode_status_ok(&status)))
        }
        Op::Metrics => {
            let guard = state.server.read().unwrap();
            let server = guard.as_ref().ok_or_else(shutting_down)?;
            let json = server.snapshot().to_value().to_json_pretty();
            Ok(Reply::Ok(json.into_bytes()))
        }
        Op::Drain => {
            state.draining.store(true, Ordering::SeqCst);
            Ok(Reply::Ok(Vec::new()))
        }
        Op::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            Ok(Reply::Ok(Vec::new()))
        }
        Op::RegisterBegin => {
            if state.draining.load(Ordering::SeqCst) {
                return Ok(shed_draining());
            }
            let total = proto::decode_register_begin(payload)?;
            if total > wire::MAX_FRAME_BYTES as u64 {
                return Err(WireError::TooLarge(total));
            }
            let token = state.next_token.fetch_add(1, Ordering::Relaxed);
            staging
                .regs
                .insert(token, PendingRegister { total: total as usize, buf: Vec::new() });
            Ok(Reply::Ok(proto::encode_u64(token)))
        }
        Op::RegisterChunk => {
            let (token, offset, chunk) = proto::decode_register_chunk(payload)?;
            let reg = staging.regs.get_mut(&token).ok_or_else(|| {
                WireError::Malformed(format!("register: unknown upload token {token}"))
            })?;
            if offset as usize != reg.buf.len() {
                return Err(WireError::Malformed(format!(
                    "register: chunk at offset {offset}, expected {}",
                    reg.buf.len()
                )));
            }
            if reg.buf.len() + chunk.len() > reg.total {
                return Err(WireError::Malformed(format!(
                    "register: upload overruns the declared {} bytes",
                    reg.total
                )));
            }
            reg.buf.extend_from_slice(chunk);
            Ok(Reply::Ok(Vec::new()))
        }
        Op::RegisterEnd => {
            let token = proto::decode_u64(payload)?;
            let reg = staging.regs.remove(&token).ok_or_else(|| {
                WireError::Malformed(format!("register: unknown upload token {token}"))
            })?;
            if reg.buf.len() != reg.total {
                return Err(WireError::Truncated {
                    needed: reg.total - reg.buf.len(),
                    have: reg.buf.len(),
                });
            }
            let image = Arc::new(wire::decode_image(&reg.buf)?);
            let (m, k) = (image.m as u64, image.k as u64);
            let guard = state.server.read().unwrap();
            let server = guard.as_ref().ok_or_else(shutting_down)?;
            let handle = server.register(image);
            let id = handle.id;
            drop(guard);
            state.images.lock().unwrap().insert(id, handle);
            Ok(Reply::Ok(proto::encode_register_ok(&ImageInfo { id, m, k })))
        }
        Op::Submit => {
            if state.draining.load(Ordering::SeqCst) {
                return Ok(shed_draining());
            }
            let (image_id, n, alpha, beta, deadline_ms) = proto::decode_submit(payload)?;
            let image = state
                .images
                .lock()
                .unwrap()
                .get(&image_id)
                .cloned()
                .ok_or_else(|| {
                    WireError::Malformed(format!("submit: image {image_id} is not registered"))
                })?;
            if n == 0 {
                return Err(WireError::Malformed("submit: N must be positive".into()));
            }
            let (m, k) = (image.image.m, image.image.k);
            // `n` arrives from the wire, so bound every staged allocation
            // (B, C, covered) before making it — a hostile Submit frame
            // must cost a typed refusal, not a multi-petabyte alloc or a
            // wrapped `k * n`.
            let b_elems = staged_elems(k, n)?;
            let c_elems = staged_elems(m, n)?;
            let covered_elems = staged_elems(1, n)?;
            let ticket = state.next_ticket.fetch_add(1, Ordering::Relaxed);
            staging.subs.insert(
                ticket,
                PendingSubmit {
                    image,
                    n,
                    alpha,
                    beta,
                    b: vec![0.0; b_elems],
                    c: vec![0.0; c_elems],
                    covered: vec![false; covered_elems],
                    t_begin: Instant::now(),
                    deadline: (deadline_ms > 0)
                        .then(|| Instant::now() + Duration::from_millis(deadline_ms)),
                },
            );
            Ok(Reply::Ok(proto::encode_u64(ticket)))
        }
        Op::SubmitChunk => {
            let (ticket, col0, ncols, b, c) = proto::decode_submit_chunk(payload)?;
            let sub = staging.subs.get_mut(&ticket).ok_or_else(|| {
                WireError::Malformed(format!("submit: unknown ticket {ticket}"))
            })?;
            let (col0, ncols) = (col0 as usize, ncols as usize);
            let (m, k, n) = (sub.image.image.m, sub.image.image.k, sub.n);
            if ncols == 0 || col0 + ncols > n {
                return Err(WireError::Malformed(format!(
                    "submit: column block [{col0}, {}) outside N = {n}",
                    col0 + ncols
                )));
            }
            if b.len() != k * ncols || c.len() != m * ncols {
                return Err(WireError::Malformed(format!(
                    "submit: block carries {} B / {} C elements (expected {} / {})",
                    b.len(),
                    c.len(),
                    k * ncols,
                    m * ncols
                )));
            }
            scatter(&b, &mut sub.b, n, col0, ncols);
            scatter(&c, &mut sub.c, n, col0, ncols);
            for covered in &mut sub.covered[col0..col0 + ncols] {
                *covered = true;
            }
            Ok(Reply::Ok(Vec::new()))
        }
        Op::SubmitEnd => {
            let ticket = proto::decode_u64(payload)?;
            let sub = staging.subs.remove(&ticket).ok_or_else(|| {
                WireError::Malformed(format!("submit: unknown ticket {ticket}"))
            })?;
            if let Some(col) = sub.covered.iter().position(|c| !c) {
                return Err(WireError::Malformed(format!(
                    "submit: column {col} never uploaded"
                )));
            }
            if state.draining.load(Ordering::SeqCst) {
                return Ok(shed_draining());
            }
            Ok(enter_pipeline(ticket, sub, state))
        }
        Op::Poll => {
            let ticket = proto::decode_u64(payload)?;
            let mut tickets = state.tickets.lock().unwrap();
            let t = tickets.get_mut(&ticket).ok_or_else(|| {
                WireError::Malformed(format!("poll: unknown ticket {ticket}"))
            })?;
            if t.cached.is_none() {
                if let Ok(resp) = t.rx.try_recv() {
                    t.cached = Some(resp);
                }
            }
            Ok(Reply::Ok(vec![t.cached.is_some() as u8]))
        }
        // Worker-tier opcodes have no meaning at the front door.
        Op::Prepare | Op::Execute | Op::Stats | Op::Evict => {
            Err(WireError::Malformed(format!("{op:?} is a worker opcode, not a front-door one")))
        }
        Op::Await | Op::Ok | Op::Err | Op::Chunk | Op::Shed => {
            Err(WireError::Malformed(format!("{op:?} sent as a single-reply request")))
        }
    }
}

/// Largest per-panel staging buffer `Op::Submit` will allocate on a
/// client's behalf, in f32 elements — the frame cap reused as a memory
/// cap, so the staged panel is never bigger than the largest frame that
/// could legally carry it.
const MAX_PANEL_ELEMS: usize = wire::MAX_FRAME_BYTES as usize / std::mem::size_of::<f32>();

/// Checked `rows * n` staging size; overflow or anything past
/// [`MAX_PANEL_ELEMS`] is a typed [`WireError::TooLarge`], never an
/// allocation.
fn staged_elems(rows: usize, n: usize) -> Result<usize, WireError> {
    rows.checked_mul(n)
        .filter(|&elems| elems <= MAX_PANEL_ELEMS)
        .ok_or_else(|| {
            WireError::TooLarge(
                (rows as u64)
                    .saturating_mul(n as u64)
                    .saturating_mul(std::mem::size_of::<f32>() as u64),
            )
        })
}

fn shutting_down() -> WireError {
    WireError::Malformed("server is shutting down".into())
}

fn shed_draining() -> Reply {
    Reply::Shed(ShedReason::Draining, "server is draining: not accepting new work".into())
}

/// Scatter a row-major `rows × ncols` column block into the full
/// row-major `rows × n` panel at column `col0`.
fn scatter(block: &[f32], panel: &mut [f32], n: usize, col0: usize, ncols: usize) {
    let rows = block.len() / ncols;
    for r in 0..rows {
        panel[r * n + col0..r * n + col0 + ncols]
            .copy_from_slice(&block[r * ncols..(r + 1) * ncols]);
    }
}

/// Gather the `[col0, col0+ncols)` column block out of a row-major
/// `rows × n` panel.
fn gather(panel: &[f32], n: usize, col0: usize, ncols: usize) -> Vec<f32> {
    let rows = panel.len() / n;
    let mut block = Vec::with_capacity(rows * ncols);
    for r in 0..rows {
        block.extend_from_slice(&panel[r * n + col0..r * n + col0 + ncols]);
    }
    block
}

/// Hand a fully staged submit to the pipeline. Opens the `net.frontend`
/// span context around the coordinator submit so the request's `request`
/// root parents under it; an admission shed surfaces immediately as a
/// typed frame (the coordinator answers sheds synchronously).
fn enter_pipeline(ticket: u64, sub: PendingSubmit, state: &Arc<FrontState>) -> Reply {
    let trace = state
        .sink
        .as_ref()
        .map(|_| (next_trace_id(), next_span_id()));
    let (n, image_id) = (sub.n, sub.image.id);
    let guard = state.server.read().unwrap();
    let Some(server) = guard.as_ref() else {
        return Reply::Err(shutting_down().to_string());
    };
    let rx = {
        let _ctx = trace.map(|(tid, sid)| push_span_context(tid, sid));
        server.submit(SpmmRequest {
            image: sub.image,
            b: sub.b,
            c: sub.c,
            n: sub.n,
            alpha: sub.alpha,
            beta: sub.beta,
            deadline: sub.deadline,
        })
    };
    drop(guard);
    // The coordinator answers admission sheds synchronously, so a
    // rejection is already waiting here — turn it into a typed frame
    // instead of parking a doomed ticket.
    let cached = rx.try_recv().ok();
    if let Some(resp) = &cached {
        if let Some(kind) = resp.rejected {
            let msg = resp.error.clone().unwrap_or_else(|| "admission rejected".into());
            let reason = match kind {
                RejectKind::QueueFull => Some(ShedReason::QueueFull),
                RejectKind::ImageQuota => Some(ShedReason::ImageQuota),
                RejectKind::DeadlineExceeded => Some(ShedReason::DeadlineExceeded),
                // Pre-pipeline refusals that are not load (shape
                // mismatch) stay plain errors.
                RejectKind::ShapeMismatch => None,
            };
            let Some(reason) = reason else {
                emit_frontend_span(state, trace, sub.t_begin, image_id, Some("error"));
                return Reply::Err(msg);
            };
            emit_frontend_span(state, trace, sub.t_begin, image_id, Some(reason.as_str()));
            return Reply::Shed(reason, msg);
        }
    }
    state.tickets.lock().unwrap().insert(
        ticket,
        Ticket { rx, cached, trace, t_begin: sub.t_begin, image: image_id, n },
    );
    Reply::Ok(Vec::new())
}

/// Emit the `net.frontend` root span for one request.
fn emit_frontend_span(
    state: &Arc<FrontState>,
    trace: Option<(u64, u64)>,
    t_begin: Instant,
    image: u64,
    outcome: Option<&str>,
) {
    if let (Some(sink), Some((trace_id, span_id))) = (state.sink.as_ref(), trace) {
        let mut span = SpanRecord::from_instants(trace_id, None, "net.frontend", t_begin, Instant::now());
        span.span_id = span_id;
        span = span.tag("image", image.to_string());
        if let Some(outcome) = outcome {
            span = span.tag("outcome", outcome.to_string());
        }
        sink.emit(span);
    }
}

/// Serve one Await: block (bounded) for the ticket's response, stream the
/// C panel back in column-block Chunk frames, close with the timing
/// frame, and emit the request's `net.frontend` span. Returns false when
/// the connection is no longer writable.
fn handle_await(
    stream: &mut TcpStream,
    payload: &[u8],
    state: &Arc<FrontState>,
    config: &FrontDoorConfig,
) -> bool {
    let (ticket_id, chunk_cols) = match proto::decode_await(payload) {
        Ok(v) => v,
        Err(e) => {
            return wire::write_frame(stream, Op::Err, format!("await: {e}").as_bytes()).is_ok()
        }
    };
    let ticket = state.tickets.lock().unwrap().remove(&ticket_id);
    let Some(mut ticket) = ticket else {
        let msg = format!("await: unknown ticket {ticket_id}");
        return wire::write_frame(stream, Op::Err, msg.as_bytes()).is_ok();
    };
    let resp = match ticket.cached.take() {
        Some(resp) => resp,
        None => match ticket.rx.recv_timeout(config.await_timeout) {
            Ok(resp) => resp,
            Err(RecvTimeoutError::Timeout) => {
                // Still running: park the ticket again, tell the client.
                let msg = format!("await: ticket {ticket_id} still running");
                state.tickets.lock().unwrap().insert(ticket_id, ticket);
                return wire::write_frame(stream, Op::Err, msg.as_bytes()).is_ok();
            }
            Err(RecvTimeoutError::Disconnected) => {
                let msg = format!("await: pipeline dropped ticket {ticket_id}");
                return wire::write_frame(stream, Op::Err, msg.as_bytes()).is_ok();
            }
        },
    };
    // A request shed after admission (deadline expiry in the batcher or
    // at dispatch pickup) surfaces as a typed Shed frame, same as a
    // synchronous admission shed — never as a generic error string.
    if let Some(kind) = resp.rejected {
        let reason = match kind {
            RejectKind::QueueFull => Some(ShedReason::QueueFull),
            RejectKind::ImageQuota => Some(ShedReason::ImageQuota),
            RejectKind::DeadlineExceeded => Some(ShedReason::DeadlineExceeded),
            RejectKind::ShapeMismatch => None,
        };
        if let Some(reason) = reason {
            let msg = resp.error.clone().unwrap_or_else(|| "request shed".into());
            let alive = wire::write_frame(stream, Op::Shed, &proto::encode_shed(reason, &msg))
                .is_ok();
            state.completed.fetch_add(1, Ordering::Relaxed);
            emit_frontend_span(
                state,
                ticket.trace,
                ticket.t_begin,
                ticket.image,
                Some(reason.as_str()),
            );
            return alive;
        }
    }
    let ok = AwaitOk {
        queue_ns: resp.timing.queue.as_nanos() as u64,
        batch_ns: resp.timing.batch.as_nanos() as u64,
        prepare_ns: resp.timing.prepare.as_nanos() as u64,
        exec_ns: resp.timing.exec.as_nanos() as u64,
        flops: resp.timing.flops,
        backend: resp.timing.backend.to_string(),
        error: resp.error.clone(),
    };
    // Stream the result panel only on success; a failed request's C is
    // not a result.
    if ok.error.is_none() {
        let n = ticket.n;
        let step = if chunk_cols == 0 { n } else { (chunk_cols as usize).min(n) };
        let mut col0 = 0usize;
        while col0 < n {
            let ncols = step.min(n - col0);
            let block = gather(&resp.c, n, col0, ncols);
            let payload = proto::encode_result_chunk(col0 as u64, ncols as u64, &block);
            if wire::write_frame(stream, Op::Chunk, &payload).is_err() {
                return false;
            }
            col0 += ncols;
        }
    }
    let alive = wire::write_frame(stream, Op::Ok, &proto::encode_await_ok(&ok)).is_ok();
    state.completed.fetch_add(1, Ordering::Relaxed);
    let outcome = ok.error.as_deref().map(|_| "error");
    emit_frontend_span(state, ticket.trace, ticket.t_begin, ticket.image, outcome);
    alive
}
