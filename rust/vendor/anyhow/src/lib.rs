//! Offline stand-in for the `anyhow` crate, vendored because the build
//! environment ships no cargo registry. Implements exactly the subset this
//! workspace uses:
//!
//! * [`Error`] — an erased error holding a context chain of messages.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`anyhow!`] / [`bail!`] — format-style construction / early return.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * Blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts concrete errors, preserving their `source()` chain.
//!
//! Display follows anyhow's conventions: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined by `": "`, and `{:?}` prints the
//! outermost message followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: a chain of human-readable frames, outermost first.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(&self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.frames[0])?;
        if self.frames.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket impl coherent.
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        Error { frames }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error with an outer context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening file: missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
        assert_eq!(Some(5).context("x").unwrap(), 5);
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("x must be nonzero, got {x}");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        let e = f(0).unwrap_err();
        assert_eq!(e.to_string(), "x must be nonzero, got 0");
        assert_eq!(anyhow!("n = {}", 7).root_cause(), "n = 7");
    }
}
